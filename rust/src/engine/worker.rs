//! The worker actor (§2.4): processes one data partition batch-at-a-
//! time, forwards results in shared [`TupleBatch`]es, and reacts to
//! control messages **between chunks**.
//!
//! The paper splits each Orleans actor into a main thread (mailbox) and
//! a data-processing thread sharing a `Paused` flag checked after every
//! iteration (Fig. 2.4). Our worker is one OS thread with two mailboxes
//! — a bounded data channel and an always-responsive
//! [`ControlInbox`](crate::engine::channel::ControlInbox). The DP loop
//! slices each incoming batch into chunks of at most
//! `ctrl_check_interval` tuples, hands each chunk to
//! [`Operator::process_batch`], and polls the inbox's atomic `pending`
//! flag between chunks. Interval 1 reproduces the paper's per-iteration
//! check exactly; larger intervals amortize the per-tuple virtual call
//! and routing cost while keeping pause latency bounded by one chunk.
//! Whenever tuple-exact positions matter — an armed local breakpoint,
//! an outstanding global-breakpoint target, or pending control-replay
//! records — the chunk length drops to 1, so conditional-breakpoint
//! culprits, COUNT-target exactness (§2.5.3) and replay positions
//! (§2.6.2) are bit-identical to the tuple-at-a-time engine.
//!
//! Chunks are zero-copy slices of the received batch (`Arc`-backed), and
//! the resumption index (§2.4.3) is a slice offset, so pausing
//! mid-batch never copies tuples.
//!
//! Responsibilities:
//! * pausing with resumption-index state save (§2.4.3) and responding
//!   to messages after pausing (§2.4.4);
//! * local conditional breakpoints (§2.5.2) and global-breakpoint
//!   target counting (§2.5.3);
//! * output batching + partitioning with Reshape's mitigation overlay
//!   (the worker-private `OutBox` scatters whole batches through
//!   [`Partitioner::route_batch`] selection vectors — one stable hash
//!   per tuple into a memoized per-batch hash column, receiver gauges
//!   bumped once per destination — and ships broadcast edges and
//!   single-run batches as clones of one shared allocation; scatter
//!   buffers are [`ColumnAppender`]s, so re-batched output stays
//!   columnar, and the memoized hash column travels with each shipped
//!   batch as a [`HashColumn`] so receivers never re-hash the
//!   partitioning key);
//! * state migration send/receive (§3.2.2, §3.5);
//! * control-replay logging and replay for fault tolerance (§2.6.2);
//! * first-output timestamps (Maestro first-response-time metric).

use crate::column::{ColumnAppender, ColumnSet};
use crate::engine::channel::{DataSender, Mailbox, RingRecvError};
use crate::engine::fault::{Fault, FaultKind, FaultPlan, LogRecord, ReplayPos, WorkerSnapshot};
use crate::engine::message::{
    BreakpointTarget, ControlMessage, DataEvent, DataMessage, HashColumn, LocalPredicate,
    WorkerEvent, WorkerId, WorkerStats,
};
use crate::engine::operator::{Emitter, Operator};
use crate::engine::partitioner::{hash_column, PartitionScheme, Partitioner, RouteVec};
use crate::tuple::{Tuple, TupleBatch};
use crate::workloads::TupleSource;
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::Ordering;
use std::sync::mpsc::Sender;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// One outgoing edge of a worker: partitioner + per-destination senders
/// and output buffers.
pub struct OutputEdge {
    /// DAG index of the destination operator (route updates address it).
    pub target_op: usize,
    /// Destination input port.
    pub port: usize,
    pub partitioner: Partitioner,
    pub senders: Vec<DataSender>,
    /// Per-destination scatter buffers. [`ColumnAppender`]s keep
    /// re-batched output columnar whenever the emitted batches were
    /// (bulk column copies / gathers instead of per-tuple clones).
    buffers: Vec<ColumnAppender>,
    /// Partitioning hashes of the buffered tuples, aligned with
    /// `buffers[d]`; shipped with the flushed batch as a
    /// [`HashColumn`] so the receiver never re-hashes the key.
    hash_bufs: Vec<Vec<u64>>,
    /// Whether `hash_bufs[d]` still covers every buffered tuple (the
    /// per-tuple emit path doesn't carry hashes and clears this).
    hash_ok: Vec<bool>,
    /// Key field hashes on this edge are computed over (`None` for
    /// keyless schemes — no hash column is tracked or shipped).
    hash_key: Option<usize>,
    /// Whether the scatter buffers accumulate columnar (mirrors
    /// `Config::columnar`; `false` pins them to row storage).
    columnar: bool,
    seqs: Vec<u64>,
}

impl OutputEdge {
    pub fn new(
        target_op: usize,
        port: usize,
        partitioner: Partitioner,
        senders: Vec<DataSender>,
    ) -> OutputEdge {
        let n = senders.len();
        // Broadcast edges keep a single buffer: the flush wraps it into
        // one shared TupleBatch and every destination receives a clone
        // of that allocation (zero per-destination tuple clones).
        let nbuf = if matches!(partitioner.scheme, PartitionScheme::Broadcast) {
            1
        } else {
            n
        };
        let hash_key = if partitioner.needs_hashes() {
            partitioner.key_field()
        } else {
            None
        };
        OutputEdge {
            target_op,
            port,
            partitioner,
            senders,
            buffers: (0..nbuf).map(|_| ColumnAppender::new(true)).collect(),
            hash_bufs: (0..nbuf).map(|_| Vec::new()).collect(),
            hash_ok: vec![true; nbuf],
            hash_key,
            columnar: true,
            seqs: vec![0; n],
        }
    }

    /// Pin the scatter buffers to the requested layout (builder-style;
    /// called while the buffers are still empty). `false` = the
    /// retained row path used when `Config::columnar` is off.
    pub fn with_columnar(mut self, columnar: bool) -> OutputEdge {
        if self.columnar != columnar {
            self.columnar = columnar;
            let nbuf = self.buffers.len();
            self.buffers = (0..nbuf).map(|_| ColumnAppender::new(columnar)).collect();
        }
        self
    }

    fn is_broadcast(&self) -> bool {
        matches!(self.partitioner.scheme, PartitionScheme::Broadcast)
    }
}

/// Everything a worker thread needs; built by the controller at deploy
/// time.
pub struct WorkerContext {
    pub id: WorkerId,
    pub mailbox: Mailbox,
    pub event_tx: Sender<WorkerEvent>,
    pub outputs: Vec<OutputEdge>,
    /// Per input port: number of upstream senders (EOF accounting).
    pub upstream_counts: Vec<usize>,
    /// Data senders to sibling workers of the same operator (state
    /// migration); index = worker idx.
    pub peers: Vec<DataSender>,
    /// Partitioning-key field per input port (None for keyless
    /// schemes) — used for the optional per-key workload distribution.
    pub port_key_fields: Vec<Option<usize>>,
    /// For source operators: the tuple source this worker drives.
    pub source: Option<Box<dyn TupleSource>>,
    /// Source workers wait for `StartSource` before emitting when
    /// false (Maestro region activation).
    pub source_autostart: bool,
    /// Tuples per output batch.
    pub batch_size: usize,
    /// Check the control flag every N tuples (1 = paper's per-iteration
    /// check).
    pub ctrl_check_interval: usize,
    /// Log control messages for fault tolerance.
    pub ft_log: bool,
    /// Restore from this snapshot (recovery).
    pub snapshot: Option<WorkerSnapshot>,
    /// Scattered-state EOF peer barrier (§3.5.4): at all-ports-EOF ship
    /// foreign runs to their owners, then wait for every sibling's
    /// `PeerEof` before finishing.
    pub scatter_merge: bool,
    /// Worker-set version this worker is born into (0 at deploy; the
    /// fence epoch for workers spawned by elastic scaling). The
    /// scatter-merge peer barrier counts `PeerEof`s of this epoch only.
    pub scale_epoch: u64,
    /// For workers spawned mid-run by elastic scaling: EOFs per port
    /// this worker will never receive because the upstream sender
    /// completed (and sent `End` to the old receiver set) before the
    /// scale fence. The worker re-checks port completion against these
    /// once its input is drained.
    pub initial_eofs: Option<Vec<usize>>,
    /// Spawn in the paused state (scale fence: new workers join the
    /// fence and start with everyone else on the closing `Resume`).
    pub start_paused: bool,
    /// Build columnar batches on the source/produce path and in rebuilt
    /// scatter buffers ([`Config::columnar`](crate::config::Config)).
    pub columnar: bool,
    /// Deterministic fault-injection plan
    /// ([`Config::fault_plan`](crate::config::Config)). The worker
    /// filters out its own panic/stall faults and the drop/delay
    /// faults of its outgoing edges; fire counters are shared across
    /// recovery respawns, so one-shot faults stay one-shot.
    pub fault_plan: FaultPlan,
    /// The execution's shared out-of-core context
    /// ([`crate::engine::spill`]): memory budget, spill counters and
    /// spill directory. Attached to the operator at construction,
    /// before any snapshot restore.
    pub spill: crate::engine::spill::SpillCtx,
}

/// Why the worker is paused (it can be paused for several reasons at
/// once; it resumes only when all causes are cleared).
#[derive(Debug, Default)]
struct PauseState {
    by_user: bool,
    by_local_bp: bool,
    /// Paused by reaching a global-breakpoint target / inquiry.
    by_target: bool,
}

impl PauseState {
    fn any(&self) -> bool {
        self.by_user || self.by_local_bp || self.by_target
    }
}

/// Global-breakpoint counting state (one active target at a time per
/// worker; the coordinator serializes assignments per breakpoint id).
#[derive(Debug, Default)]
struct TargetState {
    id: u64,
    /// Remaining COUNT/SUM amount; `None` = no active target.
    target: Option<f64>,
    sum_field: Option<usize>,
    /// Amount produced since the last assignment.
    produced_since: f64,
}

/// Reusable per-batch scatter scratch: the stable-hash column (computed
/// once per batch per key field and shared by every edge that
/// partitions on that field) and the per-destination selection vectors.
#[derive(Default)]
struct ExchangeScratch {
    hashes: Vec<u64>,
    /// Key field the hash column currently holds, for the batch being
    /// emitted (`None` = stale).
    hashes_for: Option<usize>,
    /// Shared copy of `hashes` built lazily the first time a full-size
    /// single-run batch ships it as a [`HashColumn`] (one allocation
    /// per batch no matter how many edges/destinations ship it).
    hashes_arc: Option<Arc<[u64]>>,
    routes: RouteVec,
}

struct OutBox {
    id: WorkerId,
    edges: Vec<OutputEdge>,
    batch_size: usize,
    produced: u64,
    local_bp: Option<LocalPredicate>,
    bp_hit: Option<Tuple>,
    target: TargetState,
    target_reached: bool,
    first_output_sent: bool,
    event_tx: Sender<WorkerEvent>,
    dead: bool,
    scratch: ExchangeScratch,
    /// Edge-scoped injected faults (drop/delay) whose sending side is
    /// this worker (empty outside fault-injection runs).
    faults: Vec<Fault>,
    /// Data batches sent toward each destination operator so far —
    /// the 1-based `nth` coordinate of [`FaultKind::DropNth`] /
    /// [`FaultKind::DelayNth`]. Only maintained while `faults` is
    /// non-empty.
    sent_toward: HashMap<usize, u64>,
}

impl OutBox {
    /// Injected edge-fault gate for one outgoing data batch toward
    /// `target_op`: counts the batch (1-based), fires any matching
    /// drop/delay fault, and returns `true` when the batch must be
    /// dropped on the wire.
    fn edge_fault_gate(&mut self, target_op: usize) -> bool {
        if self.faults.is_empty() {
            return false;
        }
        let n = self.sent_toward.entry(target_op).or_insert(0);
        *n += 1;
        let nth_now = *n;
        let mut drop = false;
        for f in &self.faults {
            match f.kind {
                FaultKind::DropNth { to_op, nth, .. }
                    if to_op == target_op && nth == nth_now && f.try_fire() =>
                {
                    drop = true;
                }
                FaultKind::DelayNth { to_op, nth, for_ms, .. }
                    if to_op == target_op && nth == nth_now && f.try_fire() =>
                {
                    // Per-edge FIFO is preserved — the sender simply
                    // blocks — so a delay never reorders batches.
                    std::thread::sleep(Duration::from_millis(for_ms));
                }
                _ => {}
            }
        }
        drop
    }

    /// Send one message carrying `batch` (and, when the whole batch was
    /// hashed on the scatter path, its partitioning [`HashColumn`]) to
    /// destination `d` of edge `e`.
    fn send_msg(&mut self, e: usize, d: usize, batch: TupleBatch, hashes: Option<HashColumn>) {
        let target_op = self.edges[e].target_op;
        let msg = DataMessage {
            from: self.id,
            port: self.edges[e].port,
            seq: self.edges[e].seqs[d],
            batch,
            hashes,
        };
        self.edges[e].seqs[d] += 1;
        if self.edge_fault_gate(target_op) {
            // Injected DropNth: the batch is lost on the wire.
            return;
        }
        if self.edges[e].senders[d].send(DataEvent::Batch(msg)).is_err() {
            // Receiver crashed; the whole execution is being torn down.
            self.dead = true;
        }
    }

    /// Flush buffer `d` of edge `e` (broadcast edges flush all
    /// destinations at once — they share one buffer).
    fn flush_one(&mut self, e: usize, d: usize) {
        if self.edges[e].is_broadcast() {
            self.flush_broadcast(e);
            return;
        }
        if self.edges[e].buffers[d].is_empty() {
            return;
        }
        let edge = &mut self.edges[e];
        let batch = edge.buffers[d].take_batch();
        // Ship the buffered hash column when it covers the whole batch
        // (it always does on the batch-at-a-time scatter path; the
        // per-tuple emit fallback drops it).
        let hashes = if edge.hash_ok[d] && edge.hash_bufs[d].len() == batch.len() {
            edge.hash_key.map(|key| {
                let vals: Arc<[u64]> = std::mem::take(&mut edge.hash_bufs[d]).into();
                HashColumn::new(key, vals)
            })
        } else {
            edge.hash_bufs[d].clear();
            None
        };
        edge.hash_ok[d] = true;
        self.send_msg(e, d, batch, hashes);
    }

    /// Flush a broadcast edge: wrap the single buffer into one shared
    /// batch and send a clone of it to every destination.
    fn flush_broadcast(&mut self, e: usize) {
        if self.edges[e].buffers[0].is_empty() {
            return;
        }
        let shared = self.edges[e].buffers[0].take_batch();
        for d in 0..self.edges[e].senders.len() {
            self.send_msg(e, d, shared.clone(), None);
        }
    }

    /// The emitted batch's hash column as a shippable [`HashColumn`],
    /// if edge `e` partitions on the key the scratch column was
    /// computed for. Builds the shared allocation once per batch.
    fn shipped_hashes(&mut self, e: usize) -> Option<HashColumn> {
        let key = self.edges[e].hash_key?;
        if self.scratch.hashes_for != Some(key) {
            return None;
        }
        if self.scratch.hashes_arc.is_none() {
            self.scratch.hashes_arc = Some(self.scratch.hashes.as_slice().into());
        }
        let vals = self.scratch.hashes_arc.as_ref().unwrap().clone();
        Some(HashColumn::new(key, vals))
    }

    /// Flush every buffer of edge `e`.
    fn flush_edge(&mut self, e: usize) {
        if self.edges[e].is_broadcast() {
            self.flush_broadcast(e);
        } else {
            for d in 0..self.edges[e].senders.len() {
                self.flush_one(e, d);
            }
        }
    }

    /// Flush every non-empty buffer (pause points, EOF).
    fn flush_all(&mut self) {
        for e in 0..self.edges.len() {
            self.flush_edge(e);
        }
    }

    /// Send EOF on all edges.
    fn send_eof(&mut self) {
        self.flush_all();
        for edge in &self.edges {
            for s in &edge.senders {
                let _ = s.send(DataEvent::End { from: self.id, port: edge.port });
            }
        }
    }

    /// Send a partitioning-epoch marker on edge(s) targeting `op`.
    fn send_marker(&mut self, target_op: usize, epoch: u64) {
        for e in 0..self.edges.len() {
            if self.edges[e].target_op != target_op {
                continue;
            }
            // Flush buffered data first so the marker orders correctly.
            self.flush_edge(e);
            let edge = &self.edges[e];
            for s in &edge.senders {
                let _ = s.send(DataEvent::Marker {
                    from: self.id,
                    port: edge.port,
                    epoch,
                });
            }
        }
    }

    fn note_first_output(&mut self) {
        if !self.first_output_sent {
            self.first_output_sent = true;
            let _ = self.event_tx.send(WorkerEvent::FirstOutput {
                worker: self.id,
                at: Instant::now(),
            });
        }
    }

    /// Global-breakpoint target accounting for one tuple (§2.5.3).
    fn note_target(&mut self, t: &Tuple) {
        if let Some(remaining) = self.target.target {
            let amount = match self.target.sum_field {
                None => 1.0,
                Some(f) => t.get(f).as_float().unwrap_or(0.0),
            };
            self.target.produced_since += amount;
            if self.target.produced_since >= remaining {
                self.target_reached = true;
            }
        }
    }

    /// Local conditional breakpoint (§2.5.2): record the culprit
    /// tuple; the worker loop pauses after the current chunk.
    fn note_local_bp(&mut self, t: &Tuple) {
        if let Some(p) = &self.local_bp {
            if self.bp_hit.is_none() && p(t) {
                self.bp_hit = Some(t.clone());
            }
        }
    }
}

impl Emitter for OutBox {
    fn emit(&mut self, mut t: Tuple) {
        self.produced += 1;
        self.note_first_output();
        self.note_local_bp(&t);
        self.note_target(&t);
        // Route and buffer. Single-edge unicast (the common case)
        // moves the tuple; fan-out clones.
        let n_edges = self.edges.len();
        for e in 0..n_edges {
            let last_edge = e + 1 == n_edges;
            let (base, dest) = self.edges[e].partitioner.route_with_base(&t);
            if dest == usize::MAX {
                // Broadcast: buffer once; the flush shares one
                // allocation across every destination.
                if last_edge {
                    let moved = std::mem::replace(&mut t, Tuple { values: Box::new([]) });
                    self.edges[e].buffers[0].push_owned(moved);
                } else {
                    self.edges[e].buffers[0].push_row(&t);
                }
                if self.edges[e].buffers[0].len() >= self.batch_size {
                    self.flush_broadcast(e);
                }
            } else {
                // Track routed-input accounting on the receiver gauges:
                // σ_w ("total input received", §3.4.1) on the final
                // destination, and the natural share on the base one.
                self.edges[e].senders[dest]
                    .gauges
                    .received
                    .fetch_add(1, Ordering::Relaxed);
                self.edges[e].senders[base]
                    .gauges
                    .base_received
                    .fetch_add(1, Ordering::Relaxed);
                // Per-tuple routing already discarded the hash; the
                // buffered batch can no longer ship a full hash column.
                if self.edges[e].hash_key.is_some() {
                    self.edges[e].hash_ok[dest] = false;
                }
                if last_edge {
                    let moved = std::mem::replace(&mut t, Tuple { values: Box::new([]) });
                    self.edges[e].buffers[dest].push_owned(moved);
                } else {
                    self.edges[e].buffers[dest].push_row(&t);
                }
                if self.edges[e].buffers[dest].len() >= self.batch_size {
                    self.flush_one(e, dest);
                }
            }
        }
    }

    /// Scatter a whole batch through the per-edge partitioners at batch
    /// granularity ([`Partitioner::route_batch`]): the partitioning key
    /// is hashed once per tuple into a memoized per-batch hash column
    /// (shared by every edge keyed on the same field), destinations
    /// come back as per-destination selection vectors, and the σ_w /
    /// natural-share gauges are bumped **once per destination** instead
    /// of once per tuple. Broadcast edges and single-run batches (all
    /// tuples to one destination — structurally for one-to-one edges,
    /// detected for hash/range) ship the *shared* allocation: full-size
    /// chunks forward it directly, smaller chunks buffer up to
    /// `batch_size` so message sizing matches the tuple-at-a-time
    /// engine at any `ctrl_check_interval`.
    fn emit_batch(&mut self, batch: TupleBatch) {
        let n = batch.len();
        if n == 0 {
            return;
        }
        self.produced += n as u64;
        self.note_first_output();
        if self.local_bp.is_some() {
            for t in batch.iter() {
                self.note_local_bp(t);
            }
        }
        if self.target.target.is_some() {
            for t in batch.iter() {
                self.note_target(t);
            }
        }
        // New batch: whatever hash column the scratch holds is stale.
        self.scratch.hashes_for = None;
        self.scratch.hashes_arc = None;
        for e in 0..self.edges.len() {
            if self.edges[e].is_broadcast() {
                if n >= self.batch_size {
                    // Full-size chunk: ship buffered singles first
                    // (FIFO per destination), then clones of the shared
                    // payload — zero tuple copies.
                    self.flush_broadcast(e);
                    for d in 0..self.edges[e].senders.len() {
                        self.send_msg(e, d, batch.clone(), None);
                    }
                } else {
                    // Sub-batch chunk: buffer so message sizing matches
                    // the configured batch_size; the flush still shares
                    // one allocation across destinations.
                    self.edges[e].buffers[0].append_batch(&batch);
                    if self.edges[e].buffers[0].len() >= self.batch_size {
                        self.flush_broadcast(e);
                    }
                }
                continue;
            }
            // Hash column: once per batch per key field. A message whose
            // sender memoized its hashes carries them pre-computed
            // (`DataMessage::hashes`); this covers freshly produced
            // output. Columnar batches hash with the typed
            // `Column::hash_range` kernels, rows fall back per-tuple.
            if self.edges[e].partitioner.needs_hashes() {
                let key = self.edges[e].partitioner.key_field().unwrap_or(0);
                if self.scratch.hashes_for != Some(key) {
                    hash_column(&batch, key, &mut self.scratch.hashes);
                    self.scratch.hashes_for = Some(key);
                    self.scratch.hashes_arc = None;
                }
            }
            let mut routes = std::mem::take(&mut self.scratch.routes);
            self.edges[e]
                .partitioner
                .route_batch(&batch, &self.scratch.hashes, &mut routes);
            // Natural-share gauge: one add per destination with tuples.
            for d in 0..self.edges[e].senders.len() {
                let c = routes.base_counts[d];
                if c > 0 {
                    self.edges[e].senders[d]
                        .gauges
                        .base_received
                        .fetch_add(c as i64, Ordering::Relaxed);
                }
            }
            if let Some(d) = routes.single {
                // Single-run batch: ship the shared allocation, like
                // broadcast — zero per-destination tuple clones.
                self.edges[e].senders[d]
                    .gauges
                    .received
                    .fetch_add(n as i64, Ordering::Relaxed);
                if n >= self.batch_size {
                    self.flush_one(e, d);
                    let hashes = self.shipped_hashes(e);
                    self.send_msg(e, d, batch.clone(), hashes);
                } else {
                    let hashes_for = self.scratch.hashes_for;
                    let edge = &mut self.edges[e];
                    if let Some(key) = edge.hash_key {
                        if hashes_for == Some(key)
                            && edge.hash_ok[d]
                            && edge.hash_bufs[d].len() == edge.buffers[d].len()
                        {
                            edge.hash_bufs[d].extend_from_slice(&self.scratch.hashes);
                        } else {
                            edge.hash_ok[d] = false;
                        }
                    }
                    edge.buffers[d].append_batch(&batch);
                    if self.edges[e].buffers[d].len() >= self.batch_size {
                        self.flush_one(e, d);
                    }
                }
            } else {
                for d in 0..self.edges[e].senders.len() {
                    let sel_len = routes.sel[d].len();
                    if sel_len == 0 {
                        continue;
                    }
                    self.edges[e].senders[d]
                        .gauges
                        .received
                        .fetch_add(sel_len as i64, Ordering::Relaxed);
                    // Append in batch_size-capped slices, flushing at
                    // each boundary: message sizing (and the receiver's
                    // data_queue_cap × batch_size memory bound) stays
                    // identical to the per-tuple path even when one
                    // emitted batch scatters many tuples to `d`.
                    let mut start = 0usize;
                    while start < sel_len {
                        let hashes_for = self.scratch.hashes_for;
                        let edge = &mut self.edges[e];
                        let room =
                            self.batch_size.saturating_sub(edge.buffers[d].len()).max(1);
                        let end = (start + room).min(sel_len);
                        let sel = &routes.sel[d][start..end];
                        // Gather the matching hash values alongside the
                        // tuples so the flushed batch ships them.
                        if let Some(key) = edge.hash_key {
                            if hashes_for == Some(key)
                                && edge.hash_ok[d]
                                && edge.hash_bufs[d].len() == edge.buffers[d].len()
                            {
                                let hs = &self.scratch.hashes;
                                edge.hash_bufs[d]
                                    .extend(sel.iter().map(|&i| hs[i as usize]));
                            } else {
                                edge.hash_ok[d] = false;
                            }
                        }
                        edge.buffers[d].append_gather(&batch, sel);
                        start = end;
                        if self.edges[e].buffers[d].len() >= self.batch_size {
                            self.flush_one(e, d);
                        }
                    }
                }
            }
            self.scratch.routes = routes;
        }
    }
}

/// The worker thread entry point. The whole DP loop runs under panic
/// containment: an unwinding panic — an operator bug or an injected
/// [`FaultKind::PanicAt`] — is caught here, converted into a
/// [`WorkerEvent::WorkerFailed`] for the coordinator's supervision
/// layer, and never escapes the thread. Shared-lock poisoning from the
/// unwind is tolerated by every lock site (see
/// [`crate::engine::channel`]), so a contained panic cannot cascade.
pub fn run_worker(ctx: WorkerContext, op: Box<dyn Operator>) {
    let id = ctx.id;
    let event_tx = ctx.event_tx.clone();
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(move || {
        Worker::new(ctx, op).run();
    }));
    if let Err(payload) = result {
        let cause = if let Some(s) = payload.downcast_ref::<&str>() {
            (*s).to_string()
        } else if let Some(s) = payload.downcast_ref::<String>() {
            s.clone()
        } else {
            "panic with non-string payload".to_string()
        };
        let _ = event_tx.send(WorkerEvent::WorkerFailed {
            worker: id,
            cause,
            at: Instant::now(),
        });
    }
}

struct Worker {
    id: WorkerId,
    mailbox: Mailbox,
    event_tx: Sender<WorkerEvent>,
    out: OutBox,
    op: Box<dyn Operator>,
    peers: Vec<DataSender>,
    port_key_fields: Vec<Option<usize>>,
    source: Option<Box<dyn TupleSource>>,
    source_started: bool,
    batch_size: usize,
    ctrl_check_interval: usize,
    ft_log: bool,

    pause: PauseState,
    /// Unprocessed data events stashed while paused.
    stash: VecDeque<DataEvent>,
    /// The partially processed batch + resumption index (§2.4.3).
    current: Option<(DataMessage, usize)>,
    /// EOFs seen per port.
    eofs_seen: Vec<usize>,
    upstream_counts: Vec<usize>,
    ports_done: Vec<bool>,
    finished: bool,
    /// Peer-barrier state: true while waiting for sibling PeerEofs.
    awaiting_peers: bool,
    /// PeerEofs received so far, **per worker-set epoch** (siblings can
    /// finish before we do; a scale fence bumps the epoch, so barrier
    /// announcements against a retired sibling set can never satisfy —
    /// or wedge — the rebuilt one).
    peer_eofs: HashMap<u64, usize>,
    /// Current worker-set epoch (stamped by `RescaleSelf`).
    scale_epoch: u64,
    /// A scale fence invalidated the peer barrier this worker was
    /// parked in: re-enter it (re-ship scattered parts under the
    /// re-installed state, announce EOF with the new epoch) once the
    /// re-injected input is drained.
    rebarrier: bool,
    scatter_merge: bool,
    processed: u64,
    /// Data messages dequeued so far (replay position base).
    msg_count: u64,
    /// Pending replay records sorted by position (recovery).
    replay: VecDeque<LogRecord>,
    /// Live control messages held back until replay completes (§2.6.2:
    /// "the coordinator holds new control messages for each recreated
    /// worker until the worker has replayed all its control-replay log
    /// records" — enforced worker-side here).
    held_ctrl: VecDeque<ControlMessage>,
    /// Replay-position alignment after recovery (see
    /// [`WorkerSnapshot::resume_offset`]).
    resume_msg_count: u64,
    resume_offset: usize,
    /// Markers seen per epoch (mutable-state migration sync, §3.5.3).
    marker_counts: HashMap<u64, usize>,
    /// Per-key input counts accumulated lock-free during a batch and
    /// merged into the shared `gauges.key_counts` map once per batch
    /// (the old path took the gauge lock on the hot path).
    local_key_counts: HashMap<u64, u64>,
    /// Re-evaluate port completion once input is drained (set when a
    /// scale event changed `upstream_counts` or seeded `eofs_seen`).
    recheck_ports: bool,
    /// Columnar data plane on: sources transpose generated chunks into
    /// [`ColumnSet`]-backed batches and rebuilt edges keep columnar
    /// scatter buffers.
    columnar: bool,
    busy_ns: u64,
    dead: bool,
    /// Worker-scoped injected faults (panic/stall) targeting this
    /// worker (empty outside fault-injection runs).
    faults: Vec<Fault>,
}

impl Worker {
    fn new(ctx: WorkerContext, op: Box<dyn Operator>) -> Worker {
        let ports = ctx.upstream_counts.len();
        let worker_faults = ctx.fault_plan.worker_faults(ctx.id);
        let edge_faults = ctx.fault_plan.edge_faults(ctx.id);
        let spill = ctx.spill.clone();
        let mut w = Worker {
            id: ctx.id,
            out: OutBox {
                id: ctx.id,
                edges: ctx.outputs,
                batch_size: ctx.batch_size,
                produced: 0,
                local_bp: None,
                bp_hit: None,
                target: TargetState::default(),
                target_reached: false,
                first_output_sent: false,
                event_tx: ctx.event_tx.clone(),
                dead: false,
                scratch: ExchangeScratch::default(),
                faults: edge_faults,
                sent_toward: HashMap::new(),
            },
            mailbox: ctx.mailbox,
            event_tx: ctx.event_tx,
            op,
            peers: ctx.peers,
            port_key_fields: ctx.port_key_fields,
            source: ctx.source,
            source_started: ctx.source_autostart,
            batch_size: ctx.batch_size,
            ctrl_check_interval: ctx.ctrl_check_interval.max(1),
            ft_log: ctx.ft_log,
            pause: PauseState::default(),
            stash: VecDeque::new(),
            current: None,
            eofs_seen: vec![0; ports],
            upstream_counts: ctx.upstream_counts,
            ports_done: vec![false; ports],
            finished: false,
            awaiting_peers: false,
            peer_eofs: HashMap::new(),
            scale_epoch: ctx.scale_epoch,
            rebarrier: false,
            scatter_merge: ctx.scatter_merge,
            processed: 0,
            msg_count: 0,
            replay: VecDeque::new(),
            held_ctrl: VecDeque::new(),
            resume_msg_count: u64::MAX,
            resume_offset: 0,
            marker_counts: HashMap::new(),
            local_key_counts: HashMap::new(),
            recheck_ports: false,
            columnar: ctx.columnar,
            busy_ns: 0,
            dead: false,
            faults: worker_faults,
        };
        if ctx.start_paused {
            w.pause.by_user = true;
        }
        if let Some(init) = ctx.initial_eofs {
            w.eofs_seen = init;
            w.recheck_ports = true;
        }
        // Attach before any restore so a restored spill manifest can
        // reopen its files through the execution's SpillCtx.
        w.op.attach_spill(&spill);
        if let Some(snap) = ctx.snapshot {
            w.restore(snap);
        }
        w
    }

    fn restore(&mut self, snap: WorkerSnapshot) {
        self.op.restore(snap.op_state);
        for ev in snap.pending {
            self.stash.push_back(ev);
        }
        // A checkpoint taken after an elastic source scale embeds the
        // live (re-cut) scan range as a fork — the plan-time builder
        // cannot reproduce it. Fall back to builder + seek otherwise.
        if let Some(src) = snap.source {
            self.source = Some(src);
        } else if let (Some(src), Some(pos)) = (self.source.as_mut(), snap.source_pos) {
            src.seek(pos);
        }
        self.eofs_seen = if snap.eofs_seen.is_empty() {
            vec![0; self.upstream_counts.len()]
        } else {
            snap.eofs_seen
        };
        self.msg_count = snap.msg_count;
        // The resumed batch (if any) will be message `msg_count + 1`.
        self.resume_msg_count = snap.msg_count + 1;
        self.resume_offset = snap.resume_offset;
        self.processed = snap.processed;
        self.out.produced = snap.produced;
        self.mailbox
            .gauges
            .processed
            .store(snap.processed as i64, Ordering::Relaxed);
        // Completion state. A port that was already closed at snapshot
        // time had its `finish_port` outputs emitted — and checkpointed
        // downstream — so the restored worker must neither close it nor
        // emit again; it only re-announces the closure (and, if it had
        // fully finished, completion) so the rebuilt coordinator
        // generation's region/done accounting stays consistent.
        if !snap.ports_done.is_empty() {
            self.ports_done = snap.ports_done;
        }
        for (port, done) in self.ports_done.clone().into_iter().enumerate() {
            if done {
                let _ = self
                    .event_tx
                    .send(WorkerEvent::PortCompleted { worker: self.id, port });
            }
        }
        if snap.finished {
            self.finished = true;
            let _ = self.event_tx.send(WorkerEvent::Completed {
                worker: self.id,
                stats: self.stats(),
            });
        }
    }

    fn stats(&self) -> WorkerStats {
        WorkerStats {
            processed: self.processed,
            produced: self.out.produced,
            queued: self.mailbox.gauges.queued.load(Ordering::Relaxed),
            state_tuples: self.op.state_size() as u64,
            busy_ns: self.busy_ns,
        }
    }

    /// Stamp the supervision heartbeat: a relaxed epoch-counter bump
    /// the coordinator's sweep reads lock-free. Called at the top of
    /// the run loop and inside the chunk/produce loops, so any live
    /// worker — processing, paused, parked or finished — keeps
    /// beating; only a genuine stall (or an injected
    /// [`FaultKind::StallAt`]) goes silent.
    fn beat(&self) {
        self.mailbox
            .gauges
            .heartbeat
            .fetch_add(1, Ordering::Relaxed);
    }

    /// Fire any worker-scoped injected fault due at the current
    /// processed count. Runs between chunks — the same boundary at
    /// which control messages apply — so the panic/stall position is
    /// deterministic regardless of batching or thread scheduling.
    fn check_worker_faults(&self) {
        for f in &self.faults {
            match f.kind {
                FaultKind::PanicAt { after_processed, .. }
                    if self.processed >= after_processed && f.try_fire() =>
                {
                    panic!(
                        "injected fault: worker {:?} panicked at processed={}",
                        self.id, self.processed
                    );
                }
                FaultKind::StallAt { after_processed, for_ms, .. }
                    if self.processed >= after_processed && f.try_fire() =>
                {
                    // Stall: sleep without stamping the heartbeat so
                    // the coordinator's sweep declares this worker
                    // dead by silence, not by panic.
                    std::thread::sleep(Duration::from_millis(for_ms));
                }
                _ => {}
            }
        }
    }

    fn replay_pos(&self) -> ReplayPos {
        // Source workers: position = tuples generated (deterministic
        // across recovery since sources replay identically).
        if let Some(src) = self.source.as_ref() {
            return ReplayPos { msg_count: 0, tuple_idx: src.position() };
        }
        let mut idx = self.current.as_ref().map(|(_, i)| *i).unwrap_or(0);
        // Post-recovery alignment: within the resumed batch, recovered
        // index i corresponds to original index i + resume_offset.
        if self.msg_count == self.resume_msg_count {
            idx += self.resume_offset;
        }
        ReplayPos { msg_count: self.msg_count, tuple_idx: idx }
    }

    /// Apply one control message. Returns false if the worker must die.
    fn handle_control(&mut self, msg: ControlMessage, from_replay: bool) -> bool {
        // FT logging (§2.6.2): record message + position. Replayed
        // messages are not re-logged.
        if self.ft_log && !from_replay && self.should_log(&msg) {
            let _ = self.event_tx.send(WorkerEvent::Log(LogRecord {
                worker: self.id,
                ctrl: msg.clone(),
                pos: self.replay_pos(),
            }));
        }
        match msg {
            ControlMessage::Pause => {
                self.pause.by_user = true;
                // Flush buffered output before acking: a quiesced
                // checkpoint must find every produced tuple either in a
                // receiver's channel/stash or in its state — partial
                // output batches held here would be lost on recovery.
                self.out.flush_all();
                let _ = self.event_tx.send(WorkerEvent::PausedAck {
                    worker: self.id,
                    stats: self.stats(),
                });
            }
            ControlMessage::Resume => {
                self.pause = PauseState::default();
                let _ = self
                    .event_tx
                    .send(WorkerEvent::ResumedAck { worker: self.id });
            }
            ControlMessage::QueryStats => {
                let _ = self.event_tx.send(WorkerEvent::Stats {
                    worker: self.id,
                    stats: self.stats(),
                });
            }
            ControlMessage::SetLocalBreakpoint(p) => {
                self.out.local_bp = p;
                self.out.bp_hit = None;
                self.pause.by_local_bp = false;
            }
            ControlMessage::AssignTarget(BreakpointTarget { id, amount, sum_field }) => {
                self.out.target = TargetState {
                    id,
                    target: Some(amount),
                    sum_field,
                    produced_since: 0.0,
                };
                self.out.target_reached = false;
                // A new assignment resumes a target-paused worker
                // (t4/t8 in Fig. 2.5).
                self.pause.by_target = false;
            }
            ControlMessage::Inquire { id } => {
                // Pause self and report progress (t2→t3 in Fig. 2.5).
                self.pause.by_target = true;
                let produced = self.out.target.produced_since;
                self.out.target.target = None;
                let _ = self.event_tx.send(WorkerEvent::InquiryReport {
                    worker: self.id,
                    id,
                    produced,
                });
            }
            ControlMessage::ModifyOperator(patch) => {
                // Best effort; errors surface in stats/logs not panics.
                let _ = self.op.modify(&patch);
            }
            ControlMessage::UpdateRoute { target_op, route } => {
                let epoch = route.epoch;
                for e in &mut self.out.edges {
                    if e.target_op == target_op {
                        e.partitioner.set_route(route.clone());
                    }
                }
                self.out.send_marker(target_op, epoch);
            }
            ControlMessage::SendState { to, keys, transfer_id, replicate } => {
                let state = self.op.extract_state(keys.as_deref(), replicate);
                if let Some(peer) = self.peers.get(to.idx) {
                    let _ = peer.send(DataEvent::State {
                        from: self.id,
                        state,
                        transfer_id,
                    });
                }
            }
            ControlMessage::TakeSnapshot => {
                let snap = self.make_snapshot();
                let _ = self
                    .event_tx
                    .send(WorkerEvent::Snapshot { worker: self.id, snap });
            }
            ControlMessage::Die => {
                return false;
            }
            ControlMessage::StartSource => {
                self.source_started = true;
            }
            ControlMessage::ReplayLog(records) => {
                for r in records {
                    self.replay.push_back(r);
                }
            }
            ControlMessage::ExtractScaleState { replicate, partitioned_only, preserve_routing } => {
                // Scale fence (b): unplug. Only sent while fence-paused,
                // so the input channel is quiescent. Drain it into the
                // stash either way, then surrender (move) or replicate
                // (copy) state + pending.
                while let Ok(ev) = self.mailbox.data.try_recv() {
                    self.stash.push_back(ev);
                }
                if replicate && partitioned_only {
                    // Re-shard sweep: surrender (move) only the keyed
                    // partitioned-port state, keeping pending input,
                    // the in-progress batch and the source. Mixed-port
                    // broadcast operators re-align their per-key state
                    // with `hash % n` routing when the worker set
                    // changes; broadcast-only operators surrender an
                    // empty state, making the sweep a no-op.
                    let _ = self.event_tx.send(WorkerEvent::ScaleState {
                        worker: self.id,
                        state: self.op.partitioned_state(),
                        pending: Vec::new(),
                        source: None,
                    });
                } else if replicate {
                    // Broadcast scale-up donor: copy, keep everything.
                    let mut pending: Vec<DataEvent> = Vec::new();
                    if let Some((msg, idx)) = &self.current {
                        let mut m = msg.clone();
                        m.batch = m.batch.slice_from(*idx);
                        // Keep the shipped hash column aligned with the
                        // remainder view.
                        if let Some(hc) = &mut m.hashes {
                            hc.advance(*idx);
                        }
                        pending.push(DataEvent::Batch(m));
                    }
                    pending.extend(self.stash.iter().cloned());
                    let state = self.op.replicate_broadcast_state();
                    let _ = self.event_tx.send(WorkerEvent::ScaleState {
                        worker: self.id,
                        state,
                        pending,
                        source: None,
                    });
                } else {
                    let mut pending: Vec<DataEvent> = Vec::new();
                    let mut rem_base: Option<usize> = None;
                    if let Some((msg, idx)) = self.current.take() {
                        let mut m = msg;
                        m.batch = m.batch.slice_from(idx);
                        if let Some(hc) = &mut m.hashes {
                            hc.advance(idx);
                        }
                        rem_base = Some(idx);
                        pending.push(DataEvent::Batch(m));
                    }
                    pending.extend(self.stash.drain(..));
                    // How many of the surrendered batches came from the
                    // stash/channel (vs. the remainder / synthesized
                    // buffered input) — the replay remap needs the old
                    // message numbering they occupied.
                    let stash_batches = pending
                        .iter()
                        .skip(rem_base.is_some() as usize)
                        .filter(|ev| matches!(ev, DataEvent::Batch(_)))
                        .count();
                    // The surrendered tuples leave this worker's queue;
                    // the re-injection re-adds them on their new
                    // owners' gauges.
                    let surrendered: i64 = pending
                        .iter()
                        .map(|ev| match ev {
                            DataEvent::Batch(b) => b.batch.len() as i64,
                            _ => 0,
                        })
                        .sum();
                    self.mailbox
                        .gauges
                        .queued
                        .fetch_sub(surrendered, Ordering::Relaxed);
                    // Operator-buffered input (e.g. a join's early-probe
                    // rows) re-enters the pending set as synthesized
                    // batches, so the coordinator re-routes it exactly
                    // like in-flight channel input. Not counted against
                    // `queued` — it was already counted as processed.
                    for (port, tuples) in self.op.drain_buffered_input() {
                        if tuples.is_empty() {
                            continue;
                        }
                        pending.push(DataEvent::Batch(DataMessage {
                            from: self.id,
                            port,
                            seq: 0,
                            batch: tuples.into(),
                            hashes: None,
                        }));
                    }
                    // Fence-aware replay remapping (§2.6.2 across a
                    // migration fence): the coordinator consolidates
                    // the surrendered input into one batch per
                    // (destination, port), so the old per-message
                    // replay positions no longer exist. When routing is
                    // preserved and this worker is the sole receiver,
                    // the post-fence layout is computable here — remap
                    // pending records onto it so they stay tuple-exact
                    // instead of degrading to end-of-stream force-apply.
                    if preserve_routing
                        && self.peers.len() <= 1
                        && self.source.is_none()
                        && !self.replay.is_empty()
                    {
                        self.remap_replay_positions(&pending, rem_base, stash_batches);
                    }
                    let state = if partitioned_only {
                        // Broadcast-input retiree: surrender only the
                        // keyed partitioned-port state (survivors keep
                        // their own broadcast replicas).
                        self.op.partitioned_state()
                    } else {
                        self.op.extract_state(None, false)
                    };
                    // Scan workers surrender the live source for
                    // repartitioning over the new worker set.
                    let source = self.source.take();
                    let _ = self.event_tx.send(WorkerEvent::ScaleState {
                        worker: self.id,
                        state,
                        pending,
                        source,
                    });
                }
            }
            ControlMessage::InstallState(s) => {
                self.op.install_state(s);
            }
            ControlMessage::InstallReplica(s) => {
                self.op.install_replica(s);
            }
            ControlMessage::InstallSource(slot) => {
                // Poison-tolerant: the slot is written once by the
                // coordinator, so a poisoned lock still holds a
                // coherent value.
                if let Some(src) = slot.lock().unwrap_or_else(|e| e.into_inner()).take() {
                    self.source = Some(src);
                }
            }
            ControlMessage::RescaleSelf { peers, workers, epoch } => {
                self.peers = peers;
                self.scale_epoch = epoch;
                self.op.rescale(self.id.idx, workers);
                if self.awaiting_peers {
                    // The barrier this worker was parked in counted a
                    // worker set that no longer exists; re-enter it
                    // against the new sibling set once re-injected
                    // input has drained (run loop).
                    self.awaiting_peers = false;
                    self.rebarrier = true;
                }
            }
            ControlMessage::RescaleEdge { target_op, receivers, port_schemes, senders } => {
                for e in 0..self.out.edges.len() {
                    if self.out.edges[e].target_op != target_op {
                        continue;
                    }
                    // Buffers are empty while fence-paused (Pause
                    // flushes), but flush defensively before the edge is
                    // rebuilt so no tuple can be dropped.
                    self.out.flush_edge(e);
                    let port = self.out.edges[e].port;
                    let scheme = port_schemes
                        .get(port)
                        .cloned()
                        .unwrap_or(PartitionScheme::RoundRobin);
                    self.out.edges[e] = OutputEdge::new(
                        target_op,
                        port,
                        Partitioner::new(scheme, receivers, self.id.idx),
                        senders.clone(),
                    )
                    .with_columnar(self.columnar);
                }
            }
            ControlMessage::UpdateUpstreamCount { port, count } => {
                if let Some(c) = self.upstream_counts.get_mut(port) {
                    *c = count;
                    self.recheck_ports = true;
                }
            }
            ControlMessage::RetargetEdge {
                old_target,
                old_port,
                new_target,
                new_port,
                receivers,
                scheme,
                senders,
            } => {
                // Plan-migration fence (mat insert/remove): swap the
                // *destination operator* of one output edge. Buffers
                // are empty while fence-paused, but flush defensively —
                // a buffered tuple must reach the old destination, not
                // silently ride into the new one.
                for e in 0..self.out.edges.len() {
                    if self.out.edges[e].target_op != old_target
                        || self.out.edges[e].port != old_port
                    {
                        continue;
                    }
                    self.out.flush_edge(e);
                    self.out.edges[e] = OutputEdge::new(
                        new_target,
                        new_port,
                        Partitioner::new(scheme.clone(), receivers, self.id.idx),
                        senders.clone(),
                    )
                    .with_columnar(self.columnar);
                }
            }
            ControlMessage::FenceResume => {
                // Undo only the fence's Pause; a pre-fence breakpoint or
                // target pause survives the epoch.
                self.pause.by_user = false;
                let _ = self
                    .event_tx
                    .send(WorkerEvent::ResumedAck { worker: self.id });
            }
        }
        true
    }

    /// Which control messages are logged for replay (state-changing
    /// ones; pure queries are not). Scale-fence messages are excluded:
    /// they carry live channel endpoints and are only meaningful inside
    /// the epoch that issued them — recovery re-deploys at the
    /// post-scale parallelism instead of replaying the fence.
    fn should_log(&self, msg: &ControlMessage) -> bool {
        !matches!(
            msg,
            ControlMessage::QueryStats
                | ControlMessage::TakeSnapshot
                | ControlMessage::ReplayLog(_)
                | ControlMessage::Die
                | ControlMessage::ExtractScaleState { .. }
                | ControlMessage::InstallState(_)
                | ControlMessage::InstallReplica(_)
                | ControlMessage::InstallSource(_)
                | ControlMessage::RescaleSelf { .. }
                | ControlMessage::RescaleEdge { .. }
                | ControlMessage::UpdateUpstreamCount { .. }
                | ControlMessage::RetargetEdge { .. }
                | ControlMessage::FenceResume
        )
    }

    /// Remap pending control-replay positions across a
    /// routing-preserving migration fence (single-receiver targets).
    ///
    /// The fence consolidates the surrendered input — the remainder of
    /// the partially processed batch (old message `m0`, from tuple
    /// `rem_base`), the `stash_batches` stashed/queued batches (old
    /// messages `m0+1 ..= m0+stash_batches`), and synthesized
    /// operator-buffered input (never numbered) — into **one batch per
    /// port**, re-delivered in ascending port order as new messages
    /// `m0+1 ..= m0+C` (C = non-empty ports). A replay record pointing
    /// into the surrendered window moves to its tuple's exact offset in
    /// the consolidated batch; a record beyond the window shifts by
    /// `C - stash_batches` (best effort: upstream re-produces the same
    /// post-fence batch boundaries because the fence's pause flushed it
    /// to a boundary). Records at or before the current position are
    /// already applied and stay put.
    fn remap_replay_positions(
        &mut self,
        pending: &[DataEvent],
        rem_base: Option<usize>,
        stash_batches: usize,
    ) {
        let m0 = self.msg_count;
        // The remainder's recorded positions are in *original-stream*
        // tuple coordinates; post-recovery they sit `resume_offset`
        // beyond the recovered view (see `replay_pos`).
        let rem_base_orig = rem_base.map(|b| {
            b + if m0 == self.resume_msg_count { self.resume_offset } else { 0 }
        });
        // Walk the surrendered batches in re-injection order: per-port
        // running prefixes give each old message its offset within the
        // consolidated batch for its port.
        let mut port_totals: std::collections::BTreeMap<usize, usize> =
            std::collections::BTreeMap::new();
        // old message number -> (port, prefix in consolidated batch)
        let mut old_msgs: HashMap<u64, (usize, usize)> = HashMap::new();
        let rem_off = rem_base.is_some() as usize;
        let mut bi = 0usize;
        for ev in pending {
            let DataEvent::Batch(b) = ev else { continue };
            let entry = port_totals.entry(b.port).or_insert(0);
            let prefix = *entry;
            *entry += b.batch.len();
            if rem_off == 1 && bi == 0 {
                old_msgs.insert(m0, (b.port, prefix));
            } else if bi < rem_off + stash_batches {
                old_msgs.insert(m0 + 1 + (bi - rem_off) as u64, (b.port, prefix));
            }
            bi += 1;
        }
        // Consolidated batches arrive port-ascending: new message
        // number per port.
        let new_msg: HashMap<usize, u64> = port_totals
            .keys()
            .enumerate()
            .map(|(i, &p)| (p, m0 + 1 + i as u64))
            .collect();
        let c = port_totals.len() as u64;
        let s = stash_batches as u64;
        for rec in self.replay.iter_mut() {
            let m = rec.pos.msg_count;
            let t = rec.pos.tuple_idx;
            if m == m0 {
                if let (Some(base), Some(&(port, prefix))) =
                    (rem_base_orig, old_msgs.get(&m0))
                {
                    if t >= base {
                        rec.pos = ReplayPos {
                            msg_count: new_msg[&port],
                            tuple_idx: prefix + (t - base),
                        };
                    }
                }
            } else if m > m0 && m <= m0 + s {
                if let Some(&(port, prefix)) = old_msgs.get(&m) {
                    rec.pos =
                        ReplayPos { msg_count: new_msg[&port], tuple_idx: prefix + t };
                }
            } else if m > m0 + s {
                rec.pos = ReplayPos { msg_count: (m - s) + c, tuple_idx: t };
            }
        }
        // The per-port regrouping can reorder records; the replay queue
        // must stay position-sorted for `apply_due_replays`.
        let mut recs: Vec<LogRecord> = self.replay.drain(..).collect();
        recs.sort_by_key(|r| r.pos);
        self.replay = recs.into();
    }

    fn make_snapshot(&mut self) -> WorkerSnapshot {
        // Drain the channel into the stash so the snapshot captures all
        // in-flight input (senders are paused → the channel quiesces).
        while let Ok(ev) = self.mailbox.data.try_recv() {
            self.stash.push_back(ev);
        }
        let mut pending: Vec<DataEvent> = Vec::new();
        // Remainder of the partially processed batch first
        // (resumption-index semantics). The recovered run re-dequeues
        // it, so count it as not-yet-dequeued and record the tuple
        // offset for exact replay-position alignment (Fig. 2.6).
        let mut msg_count = self.msg_count;
        let mut resume_offset = 0usize;
        if let Some((msg, idx)) = &self.current {
            let mut m = msg.clone();
            // Zero-copy: the remainder is a suffix view of the shared
            // batch (the shipped hash column advances with it).
            m.batch = m.batch.slice_from(*idx);
            if let Some(hc) = &mut m.hashes {
                hc.advance(*idx);
            }
            resume_offset = *idx;
            msg_count = msg_count.saturating_sub(1);
            pending.push(DataEvent::Batch(m));
        }
        pending.extend(self.stash.iter().cloned());
        WorkerSnapshot {
            op_state: self.op.snapshot(),
            pending,
            source_pos: self.source.as_ref().map(|s| s.position()),
            source: self.source.as_ref().and_then(|s| s.fork()),
            eofs_seen: self.eofs_seen.clone(),
            msg_count,
            resume_offset,
            processed: self.processed,
            produced: self.out.produced,
            ports_done: self.ports_done.clone(),
            finished: self.finished,
        }
    }

    /// Drain due control messages; returns false if the worker must die.
    /// While replay records are pending, live control (except `Die` and
    /// further `ReplayLog`s) is held back and delivered after replay.
    fn drain_control(&mut self) -> bool {
        while let Some(msg) = self.mailbox.control.try_recv() {
            if !self.replay.is_empty()
                && !matches!(msg, ControlMessage::Die | ControlMessage::ReplayLog(_))
            {
                self.held_ctrl.push_back(msg);
                continue;
            }
            if !self.handle_control(msg, false) {
                return false;
            }
        }
        true
    }

    /// Check replay records due at the current position and apply them;
    /// once replay completes, release held live control.
    fn apply_due_replays(&mut self) {
        while let Some(front) = self.replay.front() {
            if front.pos <= self.replay_pos() {
                let rec = self.replay.pop_front().unwrap();
                self.handle_control(rec.ctrl, true);
            } else {
                break;
            }
        }
        if self.replay.is_empty() {
            while let Some(msg) = self.held_ctrl.pop_front() {
                if !self.handle_control(msg, false) {
                    self.dead = true;
                    return;
                }
            }
        }
    }

    /// Stream ended: force-apply any replay records the recovered run
    /// never reached (degenerate positions), then release held control.
    fn finish_replays(&mut self) {
        while let Some(rec) = self.replay.pop_front() {
            self.handle_control(rec.ctrl, true);
        }
        while let Some(msg) = self.held_ctrl.pop_front() {
            if !self.handle_control(msg, false) {
                self.dead = true;
                return;
            }
        }
    }

    /// After a breakpoint hit or target reached inside process(), pause
    /// self and notify.
    fn post_tuple_checks(&mut self) {
        if let Some(t) = self.out.bp_hit.take() {
            self.pause.by_local_bp = true;
            self.out.flush_all();
            let _ = self.event_tx.send(WorkerEvent::LocalBreakpointHit {
                worker: self.id,
                tuple: t,
            });
        }
        if self.out.target_reached {
            self.out.target_reached = false;
            let id = self.out.target.id;
            let produced = self.out.target.produced_since;
            self.out.target.target = None;
            self.pause.by_target = true;
            self.out.flush_all();
            let _ = self.event_tx.send(WorkerEvent::TargetReached {
                worker: self.id,
                id,
                produced,
            });
        }
    }

    /// Chunk length for the DP loop: `ctrl_check_interval` tuples
    /// between control checks (1 = the paper's per-iteration check).
    /// Drops to single-tuple stepping whenever tuple-exact positions
    /// matter: an armed local breakpoint (exact culprit + pause point),
    /// an outstanding global-breakpoint target (exact COUNT semantics,
    /// §2.5.3), or pending replay records (exact replay positions,
    /// §2.6.2).
    fn chunk_len(&self) -> usize {
        if self.out.local_bp.is_some()
            || self.out.target.target.is_some()
            || !self.replay.is_empty()
        {
            1
        } else {
            self.ctrl_check_interval
        }
    }

    /// Process the current batch chunk-at-a-time until it is exhausted
    /// or an interruption (pause/bp) occurs. Chunks are zero-copy
    /// slices of the shared batch; the resumption index (§2.4.3) is the
    /// slice offset.
    fn process_current(&mut self) {
        let Some((msg, mut idx)) = self.current.take() else {
            return;
        };
        let port = msg.port;
        let total = msg.batch.len();
        let t0 = Instant::now();
        while idx < total {
            self.beat();
            // The between-chunk control check (§2.4.3): a single atomic
            // load unless something is pending.
            if self.mailbox.control.maybe_pending() {
                // Park the batch so control handlers (snapshot, replay
                // logging) observe the exact resumption position.
                self.current = Some((msg.clone(), idx));
                if !self.drain_control() {
                    self.dead = true;
                    return;
                }
                let (m, i) = self.current.take().unwrap();
                if self.pause.any() || self.dead {
                    // Save resumption index and exit to outer loop.
                    self.current = Some((m, i));
                    self.busy_ns += t0.elapsed().as_nanos() as u64;
                    self.update_busy_gauge();
                    self.flush_key_counts();
                    return;
                }
                idx = i;
            }
            let end = (idx + self.chunk_len()).min(total);
            let chunk = msg.batch.slice(idx, end);
            // Optional per-key workload distribution (enabled only when
            // SBK-style mitigation needs it): accumulate into the
            // worker-local map — no lock on the hot path; merged into
            // the shared gauge once per batch. A shipped hash column
            // over the tracked field supplies the key hashes directly
            // (the sender already computed them for partitioning).
            if self.mailbox.gauges.track_keys.load(Ordering::Relaxed) {
                if let Some(Some(f)) = self.port_key_fields.get(port) {
                    match &msg.hashes {
                        Some(hc) if hc.key == *f => {
                            for &h in hc.range(idx, end) {
                                *self.local_key_counts.entry(h).or_insert(0) += 1;
                            }
                        }
                        _ => {
                            for t in chunk.iter() {
                                *self
                                    .local_key_counts
                                    .entry(t.get(*f).stable_hash())
                                    .or_insert(0) += 1;
                            }
                        }
                    }
                }
            }
            // Keyed operators (hash join probe, group-by) reuse the
            // shipped partitioning hashes instead of re-hashing.
            match &msg.hashes {
                Some(hc) => self.op.process_batch_hashed(
                    &chunk,
                    hc.key,
                    hc.range(idx, end),
                    port,
                    &mut self.out,
                ),
                None => self.op.process_batch(&chunk, port, &mut self.out),
            }
            let n = (end - idx) as u64;
            idx = end;
            self.processed += n;
            if !self.faults.is_empty() {
                self.check_worker_faults();
            }
            // queued is the Reshape workload metric — chunk-level
            // freshness suffices; the other gauges update per batch.
            self.mailbox.gauges.queued.fetch_sub(n as i64, Ordering::Relaxed);
            if self.out.dead {
                self.dead = true;
                return;
            }
            self.post_tuple_checks();
            if self.pause.any() {
                if idx < total {
                    self.current = Some((msg, idx));
                }
                self.busy_ns += t0.elapsed().as_nanos() as u64;
                self.update_busy_gauge();
                self.flush_key_counts();
                return;
            }
            // Replay records due mid-batch (single-tuple chunks while
            // any are pending keep positions exact).
            if !self.replay.is_empty() {
                self.current = Some((msg.clone(), idx));
                self.apply_due_replays();
                self.current.take();
                if self.pause.any() || self.dead {
                    self.current = Some((msg, idx));
                    self.busy_ns += t0.elapsed().as_nanos() as u64;
                    self.update_busy_gauge();
                    self.flush_key_counts();
                    return;
                }
            }
        }
        self.busy_ns += t0.elapsed().as_nanos() as u64;
        self.update_busy_gauge();
        self.flush_key_counts();
    }

    /// Merge the batch-local per-key counts into the shared gauge map
    /// (one lock per batch boundary; readers poll at metric-tick
    /// cadence, so batch-granularity freshness suffices).
    fn flush_key_counts(&mut self) {
        if self.local_key_counts.is_empty() {
            return;
        }
        // Poison-tolerant: a sibling that panicked mid-flush leaves
        // per-key counts (approximate metrics) — never a cascade.
        let mut shared = self
            .mailbox
            .gauges
            .key_counts
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        for (k, v) in self.local_key_counts.drain() {
            *shared.entry(k).or_insert(0) += v;
        }
    }

    fn update_busy_gauge(&self) {
        self.mailbox
            .gauges
            .busy_ns
            .store(self.busy_ns as i64, Ordering::Relaxed);
        self.mailbox
            .gauges
            .processed
            .store(self.processed as i64, Ordering::Relaxed);
        self.mailbox
            .gauges
            .produced
            .store(self.out.produced as i64, Ordering::Relaxed);
    }

    /// Handle one dequeued data event.
    fn handle_data_event(&mut self, ev: DataEvent) {
        match ev {
            DataEvent::Batch(msg) => {
                self.msg_count += 1;
                self.current = Some((msg, 0));
                self.apply_due_replays();
            }
            DataEvent::End { port, .. } => {
                self.eofs_seen[port] += 1;
                self.try_close_port(port);
            }
            DataEvent::Marker { epoch, port, .. } => {
                let c = self.marker_counts.entry(epoch).or_insert(0);
                *c += 1;
                let expected: usize = self.upstream_counts[port];
                if *c >= expected {
                    // All upstream senders switched epochs; safe point
                    // for mutable-state migration (§3.5.3).
                    let _ = self.event_tx.send(WorkerEvent::MarkerAligned {
                        worker: self.id,
                        epoch,
                    });
                }
            }
            DataEvent::State { state, transfer_id, .. } => {
                self.op.merge_state(state);
                let _ = self.event_tx.send(WorkerEvent::StateApplied {
                    worker: self.id,
                    transfer_id,
                });
            }
            DataEvent::PeerEof { epoch, .. } => {
                // Siblings may finish before we enter the barrier;
                // count every PeerEof under its worker-set epoch.
                // Stale-epoch announcements (sent before a scale fence
                // rebuilt the sibling set) accumulate harmlessly under
                // their own key and never complete the current barrier.
                let c = self.peer_eofs.entry(epoch).or_insert(0);
                *c += 1;
                if self.awaiting_peers
                    && epoch == self.scale_epoch
                    && *c >= self.peers.len().saturating_sub(1)
                {
                    self.awaiting_peers = false;
                    self.finish_now();
                }
            }
        }
    }

    /// Close `port` if every expected upstream `End` has been counted.
    fn try_close_port(&mut self, port: usize) {
        if self.eofs_seen[port] >= self.upstream_counts[port] && !self.ports_done[port] {
            self.ports_done[port] = true;
            self.op.finish_port(port, &mut self.out);
            let _ = self.event_tx.send(WorkerEvent::PortCompleted {
                worker: self.id,
                port,
            });
            if self.ports_done.iter().all(|&d| d) {
                self.finish();
            }
        }
    }

    /// Re-evaluate every port after a scale event changed the expected
    /// sender counts (or seeded `eofs_seen` for a worker spawned
    /// mid-run). Called only once all pending input is drained, so a
    /// port can never close ahead of re-injected data.
    fn recheck_ports(&mut self) {
        self.recheck_ports = false;
        for port in 0..self.upstream_counts.len() {
            if self.upstream_counts[port] > 0 {
                self.try_close_port(port);
            }
        }
    }

    /// All ports done (or source exhausted): either finish directly or
    /// enter the scattered-state peer barrier first (§3.5.4).
    fn finish(&mut self) {
        if self.finished || self.awaiting_peers {
            return;
        }
        if self.scatter_merge && self.peers.len() > 1 {
            // Ship foreign runs to their owners (Fig. 3.11(e,f)), then
            // announce our EOF to all siblings. An owner index outside
            // the live sibling set (stale ownership after an elastic
            // scale-down) keeps its part here instead of dropping it —
            // the part is emitted with this worker's own output.
            for (owner, state) in self.op.scattered_parts() {
                let owner = owner as usize;
                if owner == self.id.idx {
                    continue;
                }
                match self.peers.get(owner) {
                    Some(p) => {
                        let _ = p.send(DataEvent::State {
                            from: self.id,
                            state,
                            transfer_id: u64::MAX, // barrier transfer
                        });
                    }
                    None => self.op.merge_state(state),
                }
            }
            let epoch = self.scale_epoch;
            for (i, p) in self.peers.iter().enumerate() {
                if i != self.id.idx {
                    let _ = p.send(DataEvent::PeerEof { from: self.id, epoch });
                }
            }
            if self.peer_eofs.get(&epoch).copied().unwrap_or(0) >= self.peers.len() - 1 {
                self.finish_now();
            } else {
                self.awaiting_peers = true;
            }
            return;
        }
        self.finish_now();
    }

    /// Flush + EOF + report.
    fn finish_now(&mut self) {
        if self.finished {
            return;
        }
        // Degenerate replay records (positions past EOF) apply now.
        if !self.replay.is_empty() || !self.held_ctrl.is_empty() {
            self.finish_replays();
        }
        self.finished = true;
        self.op.finish(&mut self.out);
        self.out.send_eof();
        // Sync the gauges one last time: `finish_port`/`finish` may have
        // emitted output (group-by results, sink deliveries) since the
        // last batch-boundary update, and gauge readers (autoscale,
        // Maestro observation) must see the final counts.
        self.update_busy_gauge();
        let _ = self.event_tx.send(WorkerEvent::Completed {
            worker: self.id,
            stats: self.stats(),
        });
    }

    /// Source-worker production step: emit up to one batch, generated
    /// and processed chunk-at-a-time with the same control cadence as
    /// the receive path.
    fn produce_from_source(&mut self) {
        let t0 = Instant::now();
        let mut emitted = 0usize;
        while emitted < self.batch_size {
            self.beat();
            if self.mailbox.control.maybe_pending() {
                break;
            }
            // Replayed control messages due at this source position.
            if !self.replay.is_empty() {
                self.apply_due_replays();
                if self.pause.any() || self.dead {
                    break;
                }
            }
            let want = self.chunk_len().min(self.batch_size - emitted);
            let Some(src) = self.source.as_mut() else { break };
            let mut rows = Vec::with_capacity(want);
            let mut eof = false;
            for _ in 0..want {
                match src.next_tuple() {
                    Some(t) => rows.push(t),
                    None => {
                        eof = true;
                        break;
                    }
                }
            }
            if !rows.is_empty() {
                let n = rows.len();
                // Columnar plane: transpose the generated chunk once at
                // the source; every downstream hop (operators, exchange
                // hashing, scatter buffers) then works column-at-a-time
                // on shared views of it. Single-tuple chunks (exact
                // control stepping) stay row-major — the transpose
                // would cost more than it saves.
                let chunk = if self.columnar && n > 1 {
                    match ColumnSet::from_rows(&rows) {
                        Some(set) => TupleBatch::from_columns(set),
                        None => TupleBatch::new(rows),
                    }
                } else {
                    TupleBatch::new(rows)
                };
                self.op.process_batch(&chunk, 0, &mut self.out);
                self.processed += n as u64;
                if !self.faults.is_empty() {
                    self.check_worker_faults();
                }
                self.mailbox
                    .gauges
                    .processed
                    .fetch_add(n as i64, Ordering::Relaxed);
                emitted += n;
                if self.out.dead {
                    self.dead = true;
                    return;
                }
                self.post_tuple_checks();
            }
            if self.pause.any() || self.dead {
                break;
            }
            if eof {
                self.busy_ns += t0.elapsed().as_nanos() as u64;
                self.update_busy_gauge();
                self.finish();
                return;
            }
        }
        self.busy_ns += t0.elapsed().as_nanos() as u64;
        self.update_busy_gauge();
    }

    fn run(mut self) {
        self.mailbox
            .gauges
            .alive_since_ns
            .store(0, Ordering::Relaxed);
        loop {
            self.beat();
            if self.dead {
                return;
            }
            if !self.faults.is_empty() {
                self.check_worker_faults();
            }
            if !self.drain_control() {
                return; // Die
            }
            if self.pause.any() {
                // Paused: stash incoming data, stay responsive to
                // control (§2.4.4).
                while let Ok(ev) = self.mailbox.data.try_recv() {
                    self.stash.push_back(ev);
                }
                if let Some(msg) = self
                    .mailbox
                    .control
                    .recv_timeout(Duration::from_millis(2))
                {
                    if !self.handle_control(msg, false) {
                        return;
                    }
                }
                continue;
            }
            // Resume a partially processed batch first.
            if self.current.is_some() {
                self.process_current();
                continue;
            }
            // Then stashed events.
            if let Some(ev) = self.stash.pop_front() {
                self.handle_data_event(ev);
                continue;
            }
            // A scale fence voided the peer barrier this worker was
            // parked in. Drain any re-injected input first (its tuples
            // belong in this worker's runs), then re-enter the barrier
            // against the new sibling set: re-ship scattered parts from
            // the re-installed state and announce EOF with the fence's
            // epoch.
            if self.rebarrier {
                match self.mailbox.data.try_recv() {
                    Ok(ev) => self.handle_data_event(ev),
                    Err(_) => {
                        self.rebarrier = false;
                        if self.ports_done.iter().all(|&d| d) && !self.finished {
                            self.finish();
                        }
                    }
                }
                continue;
            }
            if self.finished {
                // Remain responsive to control (stats queries) until the
                // controller drops our control inbox; exit when all
                // senders hung up AND controller signalled via Die, or
                // simply exit now: completed workers park until Die.
                match self
                    .mailbox
                    .control
                    .recv_timeout(Duration::from_millis(20))
                {
                    Some(msg) => {
                        if !self.handle_control(msg, false) {
                            return;
                        }
                    }
                    None => continue,
                }
                continue;
            }
            // Sources produce; non-sources receive.
            if self.source.is_some() {
                if self.source_started {
                    self.produce_from_source();
                } else {
                    // Dormant source: wait for StartSource.
                    if let Some(msg) = self
                        .mailbox
                        .control
                        .recv_timeout(Duration::from_millis(2))
                    {
                        if !self.handle_control(msg, false) {
                            return;
                        }
                    }
                }
                continue;
            }
            // A scale event changed the EOF accounting: re-evaluate port
            // completion, but only once every already-delivered event is
            // consumed (current batch, stash and channel are empty here
            // except for the channel, checked non-blockingly below) so
            // re-injected input is never outrun by an early port close.
            if self.recheck_ports {
                match self.mailbox.data.try_recv() {
                    Ok(ev) => self.handle_data_event(ev),
                    Err(_) => self.recheck_ports(),
                }
                continue;
            }
            match self.mailbox.data.recv_timeout(Duration::from_millis(2)) {
                Ok(ev) => self.handle_data_event(ev),
                Err(RingRecvError::Empty) => {}
                Err(RingRecvError::Disconnected) => {
                    // All senders gone; if EOFs were consumed we have
                    // finished already — otherwise treat as teardown.
                    if !self.finished {
                        return;
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::channel::mailbox;
    use crate::engine::partitioner::PartitionScheme;
    use crate::tuple::Value;
    use std::sync::mpsc::channel;

    /// Pass-through operator for worker tests.
    struct Identity;
    impl Operator for Identity {
        fn name(&self) -> &str {
            "identity"
        }
        fn process(&mut self, t: Tuple, _p: usize, out: &mut dyn Emitter) {
            out.emit(t);
        }
    }

    fn tuple(i: i64) -> Tuple {
        Tuple::new(vec![Value::Int(i)])
    }

    /// Spin up a single worker with one downstream collector channel.
    /// Returns (worker ctrl inbox, data sender to worker, events rx,
    /// downstream rx, join handle).
    fn single_worker(
        batch_size: usize,
    ) -> (
        std::sync::Arc<crate::engine::channel::ControlInbox>,
        DataSender,
        std::sync::mpsc::Receiver<WorkerEvent>,
        crate::engine::channel::RingReceiver,
        std::thread::JoinHandle<()>,
    ) {
        single_worker_cfg(batch_size, 1)
    }

    fn single_worker_cfg(
        batch_size: usize,
        ctrl_check_interval: usize,
    ) -> (
        std::sync::Arc<crate::engine::channel::ControlInbox>,
        DataSender,
        std::sync::mpsc::Receiver<WorkerEvent>,
        crate::engine::channel::RingReceiver,
        std::thread::JoinHandle<()>,
    ) {
        let (in_tx, in_mb) = mailbox(64);
        let (down_tx, down_rx) = mailbox(1024);
        let (ev_tx, ev_rx) = channel();
        let ctrl = in_mb.control.clone();
        let edge = OutputEdge::new(
            1,
            0,
            Partitioner::new(PartitionScheme::OneToOne, 1, 0),
            vec![down_tx],
        );
        let ctx = WorkerContext {
            id: WorkerId::new(0, 0),
            mailbox: in_mb,
            event_tx: ev_tx,
            outputs: vec![edge],
            upstream_counts: vec![1],
            peers: vec![],
            port_key_fields: vec![None],
            source: None,
            source_autostart: true,
            batch_size,
            ctrl_check_interval,
            ft_log: false,
            snapshot: None,
            scatter_merge: false,
            scale_epoch: 0,
            initial_eofs: None,
            start_paused: false,
            columnar: true,
            fault_plan: FaultPlan::default(),
            spill: crate::engine::spill::SpillCtx::default(),
        };
        let h = std::thread::spawn(move || run_worker(ctx, Box::new(Identity)));
        (ctrl, in_tx, ev_rx, down_rx.data, h)
    }

    fn send_batch(tx: &DataSender, seq: u64, tuples: Vec<Tuple>) {
        tx.send(DataEvent::Batch(DataMessage {
            from: WorkerId::new(9, 0),
            port: 0,
            seq,
            batch: tuples.into(),
            hashes: None,
        }))
        .unwrap();
    }

    #[test]
    fn worker_passes_data_through_and_completes() {
        let (ctrl, tx, ev_rx, down_rx, h) = single_worker(4);
        send_batch(&tx, 0, (0..10).map(tuple).collect());
        tx.send(DataEvent::End { from: WorkerId::new(9, 0), port: 0 })
            .unwrap();
        // Collect forwarded tuples until EOF.
        let mut got = Vec::new();
        loop {
            match down_rx.recv_timeout(Duration::from_secs(5)).unwrap() {
                DataEvent::Batch(b) => got.extend(b.batch.iter().cloned()),
                DataEvent::End { .. } => break,
                _ => {}
            }
        }
        assert_eq!(got.len(), 10);
        assert_eq!(got[3], tuple(3));
        // Completed event observed (may trail the downstream EOF).
        let deadline = Instant::now() + Duration::from_secs(5);
        let mut completed = false;
        while Instant::now() < deadline && !completed {
            if let Ok(ev) = ev_rx.recv_timeout(Duration::from_millis(50)) {
                completed = matches!(ev, WorkerEvent::Completed { .. });
            }
        }
        assert!(completed);
        ctrl.send(ControlMessage::Die, Duration::ZERO);
        h.join().unwrap();
    }

    #[test]
    fn pause_acks_and_stops_processing() {
        let (ctrl, tx, ev_rx, down_rx, h) = single_worker(400);
        // Big batch; pause mid-processing.
        send_batch(&tx, 0, (0..10_000).map(tuple).collect());
        ctrl.send(ControlMessage::Pause, Duration::ZERO);
        // Expect a PausedAck quickly.
        let deadline = Instant::now() + Duration::from_secs(5);
        let mut acked = false;
        while Instant::now() < deadline {
            if let Ok(WorkerEvent::PausedAck { .. }) =
                ev_rx.recv_timeout(Duration::from_millis(100))
            {
                acked = true;
                break;
            }
        }
        assert!(acked, "no PausedAck");
        // Drain whatever was produced pre-pause; then nothing more.
        std::thread::sleep(Duration::from_millis(50));
        while down_rx.try_recv().is_ok() {}
        std::thread::sleep(Duration::from_millis(50));
        assert!(down_rx.try_recv().is_err(), "output continued after pause");
        // Resume → completes.
        ctrl.send(ControlMessage::Resume, Duration::ZERO);
        tx.send(DataEvent::End { from: WorkerId::new(9, 0), port: 0 })
            .unwrap();
        let deadline = Instant::now() + Duration::from_secs(5);
        let mut completed = false;
        while Instant::now() < deadline {
            if let Ok(WorkerEvent::Completed { .. }) =
                ev_rx.recv_timeout(Duration::from_millis(100))
            {
                completed = true;
                break;
            }
        }
        assert!(completed);
        ctrl.send(ControlMessage::Die, Duration::ZERO);
        h.join().unwrap();
    }

    #[test]
    fn stats_query_works_while_paused() {
        let (ctrl, tx, ev_rx, _down_rx, h) = single_worker(4);
        send_batch(&tx, 0, (0..8).map(tuple).collect());
        ctrl.send(ControlMessage::Pause, Duration::ZERO);
        // Wait for ack.
        let deadline = Instant::now() + Duration::from_secs(5);
        while Instant::now() < deadline {
            if let Ok(WorkerEvent::PausedAck { .. }) =
                ev_rx.recv_timeout(Duration::from_millis(100))
            {
                break;
            }
        }
        // Query stats while paused (§2.4.4).
        ctrl.send(ControlMessage::QueryStats, Duration::ZERO);
        let deadline = Instant::now() + Duration::from_secs(5);
        let mut got_stats = false;
        while Instant::now() < deadline {
            if let Ok(WorkerEvent::Stats { .. }) =
                ev_rx.recv_timeout(Duration::from_millis(100))
            {
                got_stats = true;
                break;
            }
        }
        assert!(got_stats, "no stats reply while paused");
        ctrl.send(ControlMessage::Die, Duration::ZERO);
        h.join().unwrap();
    }

    #[test]
    fn local_breakpoint_pauses_on_match() {
        let (ctrl, tx, ev_rx, _down, h) = single_worker(400);
        let pred: LocalPredicate =
            std::sync::Arc::new(|t: &Tuple| t.get(0).as_int() == Some(5));
        ctrl.send(
            ControlMessage::SetLocalBreakpoint(Some(pred)),
            Duration::ZERO,
        );
        send_batch(&tx, 0, (0..100).map(tuple).collect());
        let deadline = Instant::now() + Duration::from_secs(5);
        let mut hit = None;
        while Instant::now() < deadline {
            if let Ok(WorkerEvent::LocalBreakpointHit { tuple: t, .. }) =
                ev_rx.recv_timeout(Duration::from_millis(100))
            {
                hit = Some(t);
                break;
            }
        }
        assert_eq!(hit.unwrap().get(0).as_int(), Some(5));
        ctrl.send(ControlMessage::Die, Duration::ZERO);
        h.join().unwrap();
    }

    #[test]
    fn count_target_pauses_at_amount() {
        let (ctrl, tx, ev_rx, _down, h) = single_worker(400);
        ctrl.send(
            ControlMessage::AssignTarget(BreakpointTarget {
                id: 1,
                amount: 7.0,
                sum_field: None,
            }),
            Duration::ZERO,
        );
        send_batch(&tx, 0, (0..100).map(tuple).collect());
        let deadline = Instant::now() + Duration::from_secs(5);
        let mut reached = None;
        while Instant::now() < deadline {
            if let Ok(WorkerEvent::TargetReached { produced, .. }) =
                ev_rx.recv_timeout(Duration::from_millis(100))
            {
                reached = Some(produced);
                break;
            }
        }
        assert_eq!(reached, Some(7.0));
        ctrl.send(ControlMessage::Die, Duration::ZERO);
        h.join().unwrap();
    }

    #[test]
    fn die_terminates_without_ack() {
        let (ctrl, _tx, ev_rx, _down, h) = single_worker(4);
        ctrl.send(ControlMessage::Die, Duration::ZERO);
        h.join().unwrap();
        // No PausedAck/Completed events.
        assert!(ev_rx.try_recv().is_err());
    }

    #[test]
    fn count_target_exact_with_chunked_interval() {
        // Even with a 64-tuple control-check interval, an armed target
        // forces single-tuple stepping: COUNT stays exact (§2.5.3).
        let (ctrl, tx, ev_rx, _down, h) = single_worker_cfg(400, 64);
        ctrl.send(
            ControlMessage::AssignTarget(BreakpointTarget {
                id: 1,
                amount: 7.0,
                sum_field: None,
            }),
            Duration::ZERO,
        );
        // Give the assignment time to land before data floods in.
        std::thread::sleep(Duration::from_millis(20));
        send_batch(&tx, 0, (0..1000).map(tuple).collect());
        let deadline = Instant::now() + Duration::from_secs(5);
        let mut reached = None;
        while Instant::now() < deadline {
            if let Ok(WorkerEvent::TargetReached { produced, .. }) =
                ev_rx.recv_timeout(Duration::from_millis(100))
            {
                reached = Some(produced);
                break;
            }
        }
        assert_eq!(reached, Some(7.0));
        ctrl.send(ControlMessage::Die, Duration::ZERO);
        h.join().unwrap();
    }

    #[test]
    fn chunked_pause_acks_quickly() {
        // Large interval, huge batch: pause latency is bounded by one
        // chunk, far below a second.
        let (ctrl, tx, ev_rx, _down, h) = single_worker_cfg(1024, 1024);
        send_batch(&tx, 0, (0..200_000).map(tuple).collect());
        let t0 = Instant::now();
        ctrl.send(ControlMessage::Pause, Duration::ZERO);
        let deadline = Instant::now() + Duration::from_secs(5);
        let mut acked = false;
        while Instant::now() < deadline {
            if let Ok(WorkerEvent::PausedAck { .. }) =
                ev_rx.recv_timeout(Duration::from_millis(100))
            {
                acked = true;
                break;
            }
        }
        assert!(acked, "no PausedAck");
        assert!(t0.elapsed() < Duration::from_secs(1), "pause not sub-second");
        ctrl.send(ControlMessage::Die, Duration::ZERO);
        h.join().unwrap();
    }

    #[test]
    fn broadcast_shares_one_allocation_across_destinations() {
        // Three downstream workers on a broadcast edge: each must
        // receive a clone of the *same* TupleBatch allocation.
        let (in_tx, in_mb) = mailbox(64);
        let mut down_txs = Vec::new();
        let mut down_rxs = Vec::new();
        for _ in 0..3 {
            let (tx, rx) = mailbox(64);
            down_txs.push(tx);
            down_rxs.push(rx);
        }
        let (ev_tx, _ev_rx) = channel();
        let ctrl = in_mb.control.clone();
        let edge = OutputEdge::new(
            1,
            0,
            Partitioner::new(PartitionScheme::Broadcast, 3, 0),
            down_txs,
        );
        let ctx = WorkerContext {
            id: WorkerId::new(0, 0),
            mailbox: in_mb,
            event_tx: ev_tx,
            outputs: vec![edge],
            upstream_counts: vec![1],
            peers: vec![],
            port_key_fields: vec![None],
            source: None,
            source_autostart: true,
            batch_size: 8,
            ctrl_check_interval: 8,
            ft_log: false,
            snapshot: None,
            scatter_merge: false,
            scale_epoch: 0,
            initial_eofs: None,
            start_paused: false,
            columnar: true,
            fault_plan: FaultPlan::default(),
            spill: crate::engine::spill::SpillCtx::default(),
        };
        let h = std::thread::spawn(move || {
            run_worker(ctx, Box::new(crate::engine::dag::PassThrough))
        });
        send_batch(&in_tx, 0, (0..8).map(tuple).collect());
        in_tx
            .send(DataEvent::End { from: WorkerId::new(9, 0), port: 0 })
            .unwrap();
        let mut received = Vec::new();
        for rx in &down_rxs {
            loop {
                match rx.data.recv_timeout(Duration::from_secs(5)).unwrap() {
                    DataEvent::Batch(b) if !b.batch.is_empty() => {
                        received.push(b.batch);
                        break;
                    }
                    DataEvent::End { .. } => panic!("EOF before data"),
                    _ => {}
                }
            }
        }
        assert_eq!(received.len(), 3);
        assert_eq!(received[0].len(), 8);
        assert!(
            crate::tuple::TupleBatch::ptr_eq(&received[0], &received[1])
                && crate::tuple::TupleBatch::ptr_eq(&received[1], &received[2]),
            "broadcast destinations did not share one allocation"
        );
        ctrl.send(ControlMessage::Die, Duration::ZERO);
        h.join().unwrap();
    }

    #[test]
    fn hash_partitioned_edges_ship_the_hash_column() {
        // A hash-partitioned edge scatters batch-at-a-time; every
        // shipped message must carry the memoized hash column, and its
        // values must equal the per-tuple stable hashes of the key.
        let (in_tx, in_mb) = mailbox(64);
        let mut down_txs = Vec::new();
        let mut down_rxs = Vec::new();
        for _ in 0..2 {
            let (tx, rx) = mailbox(64);
            down_txs.push(tx);
            down_rxs.push(rx);
        }
        let (ev_tx, _ev_rx) = channel();
        let ctrl = in_mb.control.clone();
        let edge = OutputEdge::new(
            1,
            0,
            Partitioner::new(PartitionScheme::Hash { key: 0 }, 2, 0),
            down_txs,
        );
        let ctx = WorkerContext {
            id: WorkerId::new(0, 0),
            mailbox: in_mb,
            event_tx: ev_tx,
            outputs: vec![edge],
            upstream_counts: vec![1],
            peers: vec![],
            port_key_fields: vec![None],
            source: None,
            source_autostart: true,
            batch_size: 4,
            ctrl_check_interval: 32,
            ft_log: false,
            snapshot: None,
            scatter_merge: false,
            scale_epoch: 0,
            initial_eofs: None,
            start_paused: false,
            columnar: true,
            fault_plan: FaultPlan::default(),
            spill: crate::engine::spill::SpillCtx::default(),
        };
        let h = std::thread::spawn(move || {
            run_worker(ctx, Box::new(crate::engine::dag::PassThrough))
        });
        send_batch(&in_tx, 0, (0..32).map(tuple).collect());
        in_tx
            .send(DataEvent::End { from: WorkerId::new(9, 0), port: 0 })
            .unwrap();
        let mut seen = 0usize;
        for rx in &down_rxs {
            loop {
                match rx.data.recv_timeout(Duration::from_secs(5)).unwrap() {
                    DataEvent::Batch(b) => {
                        let hc = b.hashes.as_ref().expect("batch shipped without hashes");
                        assert_eq!(hc.key, 0);
                        assert_eq!(hc.len(), b.batch.len());
                        for (i, t) in b.batch.iter().enumerate() {
                            assert_eq!(
                                hc.range(i, i + 1)[0],
                                t.get(0).stable_hash(),
                                "shipped hash differs from the key's stable hash"
                            );
                        }
                        seen += b.batch.len();
                    }
                    DataEvent::End { .. } => break,
                    _ => {}
                }
            }
        }
        assert_eq!(seen, 32);
        ctrl.send(ControlMessage::Die, Duration::ZERO);
        h.join().unwrap();
    }

    #[test]
    fn injected_panic_is_contained_as_worker_failed() {
        let (in_tx, in_mb) = mailbox(64);
        let (down_tx, _down_rx) = mailbox(1024);
        let (ev_tx, ev_rx) = channel();
        let edge = OutputEdge::new(
            1,
            0,
            Partitioner::new(PartitionScheme::OneToOne, 1, 0),
            vec![down_tx],
        );
        let mut plan = FaultPlan::default();
        plan.push(Fault::panic_at(WorkerId::new(0, 0), 5));
        let ctx = WorkerContext {
            id: WorkerId::new(0, 0),
            mailbox: in_mb,
            event_tx: ev_tx,
            outputs: vec![edge],
            upstream_counts: vec![1],
            peers: vec![],
            port_key_fields: vec![None],
            source: None,
            source_autostart: true,
            batch_size: 4,
            ctrl_check_interval: 1,
            ft_log: false,
            snapshot: None,
            scatter_merge: false,
            scale_epoch: 0,
            initial_eofs: None,
            start_paused: false,
            columnar: true,
            fault_plan: plan,
            spill: crate::engine::spill::SpillCtx::default(),
        };
        let h = std::thread::spawn(move || run_worker(ctx, Box::new(Identity)));
        send_batch(&in_tx, 0, (0..20).map(tuple).collect());
        // The thread must exit via a contained WorkerFailed event — the
        // join succeeds (the panic never escapes) and the event names
        // the injected cause.
        let deadline = Instant::now() + Duration::from_secs(5);
        let mut failed = false;
        while Instant::now() < deadline && !failed {
            if let Ok(WorkerEvent::WorkerFailed { worker, cause, .. }) =
                ev_rx.recv_timeout(Duration::from_millis(100))
            {
                assert_eq!(worker, WorkerId::new(0, 0));
                assert!(cause.contains("injected fault"), "cause: {cause}");
                failed = true;
            }
        }
        assert!(failed, "no WorkerFailed event");
        h.join().unwrap();
    }
}

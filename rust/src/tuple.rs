//! The relational data model: [`Value`], [`Tuple`], [`Schema`].
//!
//! The paper (§2.2.1) "focuses on the relational data model, in which
//! data is modeled as bags of tuples". Strings are `Arc<str>` so that
//! tuple clones along fan-out edges (replication, broadcast of heavy
//! hitters) are cheap.

use std::fmt;
use std::sync::Arc;

/// A single field value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Int(i64),
    Float(f64),
    Str(Arc<str>),
}

impl Value {
    pub fn str(s: &str) -> Value {
        Value::Str(Arc::from(s))
    }

    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Stable 64-bit hash of the value (used for hash partitioning).
    /// FNV-1a — deterministic across runs, unlike `DefaultHasher` with
    /// random keys, which matters for fault-tolerance replay.
    pub fn stable_hash(&self) -> u64 {
        const OFFSET: u64 = 0xcbf29ce484222325;
        const PRIME: u64 = 0x100000001b3;
        let mut h = OFFSET;
        let mut eat = |bytes: &[u8]| {
            for &b in bytes {
                h ^= b as u64;
                h = h.wrapping_mul(PRIME);
            }
        };
        match self {
            Value::Null => eat(&[0]),
            Value::Int(i) => {
                eat(&[1]);
                eat(&i.to_le_bytes());
            }
            Value::Float(f) => {
                eat(&[2]);
                eat(&f.to_bits().to_le_bytes());
            }
            Value::Str(s) => {
                eat(&[3]);
                eat(s.as_bytes());
            }
        }
        h
    }

    /// Approximate in-memory size in bytes (used by Maestro's
    /// materialization-size accounting, Figs. 4.23/4.24).
    pub fn byte_size(&self) -> usize {
        match self {
            Value::Null => 1,
            Value::Int(_) => 8,
            Value::Float(_) => 8,
            Value::Str(s) => 16 + s.len(),
        }
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Null => write!(f, "NULL"),
            Value::Int(i) => write!(f, "{i}"),
            Value::Float(x) => write!(f, "{x}"),
            Value::Str(s) => write!(f, "{s}"),
        }
    }
}

/// Total order over values for sort operators: NULL < Int/Float < Str;
/// numeric values compare numerically across Int/Float.
pub fn value_cmp(a: &Value, b: &Value) -> std::cmp::Ordering {
    use std::cmp::Ordering::*;
    use Value::*;
    match (a, b) {
        (Null, Null) => Equal,
        (Null, _) => Less,
        (_, Null) => Greater,
        (Int(x), Int(y)) => x.cmp(y),
        (Float(x), Float(y)) => x.partial_cmp(y).unwrap_or(Equal),
        (Int(x), Float(y)) => (*x as f64).partial_cmp(y).unwrap_or(Equal),
        (Float(x), Int(y)) => x.partial_cmp(&(*y as f64)).unwrap_or(Equal),
        (Str(x), Str(y)) => x.cmp(y),
        (Str(_), _) => Greater,
        (_, Str(_)) => Less,
    }
}

/// A tuple: a boxed slice of values. Field access is positional; the
/// [`Schema`] maps names to positions at plan-compile time so the hot
/// path never does string lookups.
#[derive(Clone, Debug, PartialEq)]
pub struct Tuple {
    pub values: Box<[Value]>,
}

impl Tuple {
    pub fn new(values: Vec<Value>) -> Tuple {
        Tuple { values: values.into_boxed_slice() }
    }

    #[inline]
    pub fn get(&self, idx: usize) -> &Value {
        &self.values[idx]
    }

    pub fn arity(&self) -> usize {
        self.values.len()
    }

    /// Concatenate two tuples (join output).
    pub fn concat(&self, other: &Tuple) -> Tuple {
        let mut v = Vec::with_capacity(self.values.len() + other.values.len());
        v.extend_from_slice(&self.values);
        v.extend_from_slice(&other.values);
        Tuple::new(v)
    }

    pub fn byte_size(&self) -> usize {
        8 + self.values.iter().map(Value::byte_size).sum::<usize>()
    }
}

impl fmt::Display for Tuple {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "(")?;
        for (i, v) in self.values.iter().enumerate() {
            if i > 0 {
                write!(f, ", ")?;
            }
            write!(f, "{v}")?;
        }
        write!(f, ")")
    }
}

/// Field types for schema declaration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FieldType {
    Int,
    Float,
    Str,
}

/// A named, typed schema.
#[derive(Clone, Debug, PartialEq)]
pub struct Schema {
    pub fields: Vec<(String, FieldType)>,
}

impl Schema {
    pub fn new(fields: &[(&str, FieldType)]) -> Schema {
        Schema {
            fields: fields
                .iter()
                .map(|(n, t)| (n.to_string(), *t))
                .collect(),
        }
    }

    /// Position of a field by name.
    pub fn index_of(&self, name: &str) -> Option<usize> {
        self.fields.iter().position(|(n, _)| n == name)
    }

    /// Schema of a join output (concatenation; right-side names prefixed
    /// on collision).
    pub fn concat(&self, other: &Schema) -> Schema {
        let mut fields = self.fields.clone();
        for (n, t) in &other.fields {
            let name = if self.index_of(n).is_some() {
                format!("r_{n}")
            } else {
                n.clone()
            };
            fields.push((name, *t));
        }
        Schema { fields }
    }

    pub fn arity(&self) -> usize {
        self.fields.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stable_hash_is_stable() {
        let v = Value::str("california");
        assert_eq!(v.stable_hash(), Value::str("california").stable_hash());
        assert_ne!(v.stable_hash(), Value::str("arizona").stable_hash());
        assert_ne!(Value::Int(1).stable_hash(), Value::Int(2).stable_hash());
        // Int and Float with same numeric value hash differently (typed).
        assert_ne!(
            Value::Int(1).stable_hash(),
            Value::Float(1.0).stable_hash()
        );
    }

    #[test]
    fn value_order_total() {
        use std::cmp::Ordering::*;
        assert_eq!(value_cmp(&Value::Null, &Value::Int(0)), Less);
        assert_eq!(value_cmp(&Value::Int(2), &Value::Float(2.5)), Less);
        assert_eq!(value_cmp(&Value::Float(3.0), &Value::Int(3)), Equal);
        assert_eq!(value_cmp(&Value::str("b"), &Value::str("a")), Greater);
        assert_eq!(value_cmp(&Value::str("a"), &Value::Int(9)), Greater);
    }

    #[test]
    fn tuple_concat() {
        let a = Tuple::new(vec![Value::Int(1)]);
        let b = Tuple::new(vec![Value::str("x"), Value::Float(2.0)]);
        let c = a.concat(&b);
        assert_eq!(c.arity(), 3);
        assert_eq!(c.get(1).as_str(), Some("x"));
    }

    #[test]
    fn schema_lookup_and_concat() {
        let s1 = Schema::new(&[("id", FieldType::Int), ("loc", FieldType::Str)]);
        let s2 = Schema::new(&[("id", FieldType::Int), ("val", FieldType::Float)]);
        assert_eq!(s1.index_of("loc"), Some(1));
        let j = s1.concat(&s2);
        assert_eq!(j.arity(), 4);
        assert_eq!(j.index_of("r_id"), Some(2));
        assert_eq!(j.index_of("val"), Some(3));
    }

    #[test]
    fn byte_size_counts_strings() {
        let t = Tuple::new(vec![Value::str("abcd"), Value::Int(5)]);
        assert_eq!(t.byte_size(), 8 + (16 + 4) + 8);
    }
}

//! The relational data model: [`Value`], [`Tuple`], [`TupleBatch`],
//! [`Schema`].
//!
//! The paper (§2.2.1) "focuses on the relational data model, in which
//! data is modeled as bags of tuples". Strings are `Arc<str>` so that
//! tuple clones along fan-out edges (replication, broadcast of heavy
//! hitters) are cheap.
//!
//! The engine's unit of data movement is the [`TupleBatch`]: an
//! immutable run of tuples behind one shared allocation. Batches are
//! sliced (for the worker's resumption index and control-check
//! chunking) and fanned out (broadcast, replicate, Reshape
//! heavy-hitter split) without copying tuples — every view shares the
//! one allocation.
//!
//! A batch carries its tuples in one (or, after lazy conversion, both)
//! of two physical layouts:
//!
//! * **row-major** — a `[Tuple]` run, the layout operators see through
//!   [`TupleBatch::as_slice`] / [`TupleBatch::get`];
//! * **columnar** — a [`crate::column::ColumnSet`] of typed
//!   struct-of-arrays vectors, exposed through
//!   [`TupleBatch::columns`], which the hot paths (hash routing,
//!   filters, projections, gathers) consume column-at-a-time.
//!
//! Conversion is lazy and cached in both directions: a columnar batch
//! materializes rows only when a row-path consumer asks for them, and
//! a row batch transposes only when [`TupleBatch::ensure_columns`] is
//! called. Slicing and cloning never convert — views carry the same
//! `[start, end)` window over whichever layouts exist.

use crate::column::ColumnSet;
use std::fmt;
use std::sync::{Arc, OnceLock};

/// A single field value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Int(i64),
    Float(f64),
    Str(Arc<str>),
}

impl Value {
    pub fn str(s: &str) -> Value {
        Value::Str(Arc::from(s))
    }

    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Stable 64-bit hash of the value (used for hash partitioning,
    /// SBK key sets, and keyed operator-state scopes).
    ///
    /// Deterministic and seed-free — unlike `DefaultHasher`'s random
    /// keys — so hash routes are byte-stable across runs, which
    /// fault-tolerance replay (§2.6.2) depends on. Scalars hash in one
    /// full-avalanche round; strings are eaten a 64-bit word at a time
    /// (wyhash-style) instead of the byte-at-a-time FNV loop this
    /// replaced (one multiply per 8 bytes instead of per byte).
    ///
    /// Type tags keep `Int(1)`, `Float(1.0)` and `Str` values in
    /// disjoint hash families. `-0.0` normalizes to `0.0` before
    /// hashing: the two compare equal under `PartialEq`, so they must
    /// co-partition — hashing the raw sign bit would route one logical
    /// key to two different workers.
    ///
    /// The columnar kernels ([`crate::column::Column::hash_range`])
    /// reproduce this function byte-exactly over typed vectors; any
    /// change here must be mirrored there.
    pub fn stable_hash(&self) -> u64 {
        match self {
            Value::Null => mix64(TAG_NULL),
            Value::Int(i) => mix64((*i as u64) ^ TAG_INT),
            Value::Float(f) => {
                let bits = if *f == 0.0 { 0 } else { f.to_bits() };
                mix64(bits ^ TAG_FLOAT)
            }
            Value::Str(s) => hash_bytes(s.as_bytes()),
        }
    }

    /// Approximate in-memory size in bytes (used by Maestro's
    /// materialization-size accounting, Figs. 4.23/4.24).
    pub fn byte_size(&self) -> usize {
        match self {
            Value::Null => 1,
            Value::Int(_) => 8,
            Value::Float(_) => 8,
            Value::Str(s) => 16 + s.len(),
        }
    }
}

// Type tags xor-ed into scalar hashes (arbitrary odd 64-bit constants)
// so equal bit patterns of different types land in disjoint families.
// pub(crate): the columnar hash kernels in `column` reproduce
// `stable_hash` with the same constants.
pub(crate) const TAG_NULL: u64 = 0x6c62_272e_07bb_0142;
pub(crate) const TAG_INT: u64 = 0xa076_1d64_78bd_642f;
pub(crate) const TAG_FLOAT: u64 = 0xe703_7ed1_a0b4_28db;
const TAG_STR: u64 = 0x8ebc_6af0_9c88_c6e3;

/// SplitMix64 finalizer: a full-avalanche bijection on `u64`, so every
/// input bit flips ~half the output bits — what `hash % receivers`
/// needs to spread consecutive keys evenly.
#[inline]
pub(crate) fn mix64(mut x: u64) -> u64 {
    x ^= x >> 30;
    x = x.wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

/// Word-at-a-time byte-string hash: one multiply-rotate round per
/// 64-bit word (FxHash-style), finalized by [`mix64`]. The length is
/// folded into the seed, so the zero-padded tail word is unambiguous.
#[inline]
pub(crate) fn hash_bytes(bytes: &[u8]) -> u64 {
    const M: u64 = 0x517c_c1b7_2722_0a95;
    let mut h = TAG_STR ^ (bytes.len() as u64).wrapping_mul(M);
    let mut chunks = bytes.chunks_exact(8);
    for c in chunks.by_ref() {
        let w = u64::from_le_bytes(c.try_into().unwrap());
        h = (h ^ w).wrapping_mul(M).rotate_left(23);
    }
    let rem = chunks.remainder();
    if !rem.is_empty() {
        let mut w = 0u64;
        for (i, &b) in rem.iter().enumerate() {
            w |= (b as u64) << (8 * i);
        }
        h = (h ^ w).wrapping_mul(M).rotate_left(23);
    }
    mix64(h)
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Null => write!(f, "NULL"),
            Value::Int(i) => write!(f, "{i}"),
            Value::Float(x) => write!(f, "{x}"),
            Value::Str(s) => write!(f, "{s}"),
        }
    }
}

/// Total order over values for sort operators: NULL < Int/Float < Str;
/// numeric values compare numerically across Int/Float.
pub fn value_cmp(a: &Value, b: &Value) -> std::cmp::Ordering {
    use std::cmp::Ordering::*;
    use Value::*;
    match (a, b) {
        (Null, Null) => Equal,
        (Null, _) => Less,
        (_, Null) => Greater,
        (Int(x), Int(y)) => x.cmp(y),
        (Float(x), Float(y)) => x.partial_cmp(y).unwrap_or(Equal),
        (Int(x), Float(y)) => (*x as f64).partial_cmp(y).unwrap_or(Equal),
        (Float(x), Int(y)) => x.partial_cmp(&(*y as f64)).unwrap_or(Equal),
        (Str(x), Str(y)) => x.cmp(y),
        (Str(_), _) => Greater,
        (_, Str(_)) => Less,
    }
}

/// A tuple: a boxed slice of values. Field access is positional; the
/// [`Schema`] maps names to positions at plan-compile time so the hot
/// path never does string lookups.
#[derive(Clone, Debug, PartialEq)]
pub struct Tuple {
    pub values: Box<[Value]>,
}

impl Tuple {
    pub fn new(values: Vec<Value>) -> Tuple {
        Tuple { values: values.into_boxed_slice() }
    }

    #[inline]
    pub fn get(&self, idx: usize) -> &Value {
        &self.values[idx]
    }

    pub fn arity(&self) -> usize {
        self.values.len()
    }

    /// Concatenate two tuples (join output).
    pub fn concat(&self, other: &Tuple) -> Tuple {
        let mut v = Vec::with_capacity(self.values.len() + other.values.len());
        v.extend_from_slice(&self.values);
        v.extend_from_slice(&other.values);
        Tuple::new(v)
    }

    pub fn byte_size(&self) -> usize {
        8 + self.values.iter().map(Value::byte_size).sum::<usize>()
    }
}

impl fmt::Display for Tuple {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "(")?;
        for (i, v) in self.values.iter().enumerate() {
            if i > 0 {
                write!(f, ", ")?;
            }
            write!(f, "{v}")?;
        }
        write!(f, ")")
    }
}

/// The shared storage behind a [`TupleBatch`]: the same tuples in up
/// to two physical layouts, each materialized at most once. Every
/// batch view (clone/slice) points at the same `BatchData`, so a lazy
/// conversion done through one view is visible to all of them.
#[derive(Debug)]
struct BatchData {
    rows: OnceLock<Box<[Tuple]>>,
    /// `None` inside the lock = transpose was attempted and refused
    /// (ragged arities); such batches stay row-major forever.
    cols: OnceLock<Option<ColumnSet>>,
}

/// A borrowed window onto a batch's columnar layout: the column set
/// plus the view bounds `[start, end)`. All columnar kernels take the
/// bounds explicitly, so slicing stays zero-copy in both layouts.
#[derive(Clone, Copy, Debug)]
pub struct ColumnsView<'a> {
    /// The batch's full column set (unsliced).
    pub set: &'a ColumnSet,
    /// First row of the view within `set`.
    pub start: usize,
    /// One past the last row of the view within `set`.
    pub end: usize,
}

impl ColumnsView<'_> {
    /// Rows in the view.
    pub fn len(&self) -> usize {
        self.end - self.start
    }

    /// Whether the view is empty.
    pub fn is_empty(&self) -> bool {
        self.start == self.end
    }
}

/// An immutable batch of tuples behind a shared allocation.
///
/// `clone` and [`slice`](TupleBatch::slice) are O(1): they bump the
/// `Arc` and adjust the view bounds. This is what makes broadcast
/// edges zero-copy — every destination receives a clone of the same
/// batch — and what lets the worker chunk a batch at
/// `ctrl_check_interval` without materializing sub-batches.
///
/// Batches built by the columnar exchange hold a
/// [`ColumnSet`] instead of (or in addition to) the row run; the row
/// view is materialized lazily, once, on first row access. See the
/// module docs for the layout policy.
#[derive(Clone, Debug)]
pub struct TupleBatch {
    data: Arc<BatchData>,
    start: usize,
    end: usize,
}

impl TupleBatch {
    pub fn new(tuples: Vec<Tuple>) -> TupleBatch {
        let end = tuples.len();
        let rows = OnceLock::new();
        let _ = rows.set(tuples.into_boxed_slice());
        TupleBatch {
            data: Arc::new(BatchData { rows, cols: OnceLock::new() }),
            start: 0,
            end,
        }
    }

    /// A batch born columnar (the exchange's scatter buffers and
    /// columnar operators produce these). Rows materialize lazily.
    pub fn from_columns(set: ColumnSet) -> TupleBatch {
        let end = set.len();
        let cols = OnceLock::new();
        let _ = cols.set(Some(set));
        TupleBatch {
            data: Arc::new(BatchData { rows: OnceLock::new(), cols }),
            start: 0,
            end,
        }
    }

    pub fn empty() -> TupleBatch {
        TupleBatch::new(Vec::new())
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.end - self.start
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.start == self.end
    }

    /// The full row run, transposing out of the columnar layout on
    /// first use (cached for all views of this storage).
    fn rows_all(&self) -> &[Tuple] {
        self.data.rows.get_or_init(|| {
            let set = self
                .data
                .cols
                .get()
                .and_then(|c| c.as_ref())
                .expect("TupleBatch has neither rows nor columns");
            set.to_rows(0, set.len()).into_boxed_slice()
        })
    }

    #[inline]
    pub fn get(&self, idx: usize) -> &Tuple {
        &self.rows_all()[self.start + idx]
    }

    #[inline]
    pub fn as_slice(&self) -> &[Tuple] {
        &self.rows_all()[self.start..self.end]
    }

    /// The columnar layout of this view, if already materialized.
    /// Hot paths branch on this: `Some` takes the column kernels,
    /// `None` falls back to rows without forcing a transpose.
    pub fn columns(&self) -> Option<ColumnsView<'_>> {
        let set = self.data.cols.get()?.as_ref()?;
        Some(ColumnsView { set, start: self.start, end: self.end })
    }

    /// Whether the columnar layout is materialized.
    pub fn has_columns(&self) -> bool {
        matches!(self.data.cols.get(), Some(Some(_)))
    }

    /// The columnar layout, transposing from rows on first use
    /// (cached). Returns `None` only for ragged batches (mixed
    /// arities), which stay row-major.
    pub fn ensure_columns(&self) -> Option<ColumnsView<'_>> {
        let set = self
            .data
            .cols
            .get_or_init(|| ColumnSet::from_rows(self.rows_all()))
            .as_ref()?;
        Some(ColumnsView { set, start: self.start, end: self.end })
    }

    pub fn iter(&self) -> std::slice::Iter<'_, Tuple> {
        self.as_slice().iter()
    }

    /// Zero-copy sub-view `[start, end)` of this view (shares storage).
    pub fn slice(&self, start: usize, end: usize) -> TupleBatch {
        assert!(start <= end && end <= self.len());
        TupleBatch {
            data: self.data.clone(),
            start: self.start + start,
            end: self.start + end,
        }
    }

    /// Zero-copy suffix view from `start` (resumption-index slicing).
    pub fn slice_from(&self, start: usize) -> TupleBatch {
        self.slice(start, self.len())
    }

    /// Owned copy of the view's tuples.
    pub fn to_vec(&self) -> Vec<Tuple> {
        self.as_slice().to_vec()
    }

    /// Whether two batches share the same underlying storage
    /// (used to assert that fan-out edges did not copy tuples).
    pub fn ptr_eq(a: &TupleBatch, b: &TupleBatch) -> bool {
        Arc::ptr_eq(&a.data, &b.data)
    }

    /// Approximate in-memory size of the viewed tuples. Computed from
    /// whichever layout is materialized (both agree byte-for-byte);
    /// never forces a conversion.
    pub fn byte_size(&self) -> usize {
        if let Some(rows) = self.data.rows.get() {
            rows[self.start..self.end].iter().map(Tuple::byte_size).sum()
        } else if let Some(cv) = self.columns() {
            cv.set.byte_size_range(cv.start, cv.end)
        } else {
            0
        }
    }
}

impl Default for TupleBatch {
    fn default() -> TupleBatch {
        TupleBatch::empty()
    }
}

impl From<Vec<Tuple>> for TupleBatch {
    fn from(tuples: Vec<Tuple>) -> TupleBatch {
        TupleBatch::new(tuples)
    }
}

impl FromIterator<Tuple> for TupleBatch {
    fn from_iter<I: IntoIterator<Item = Tuple>>(iter: I) -> TupleBatch {
        TupleBatch::new(iter.into_iter().collect())
    }
}

impl<'a> IntoIterator for &'a TupleBatch {
    type Item = &'a Tuple;
    type IntoIter = std::slice::Iter<'a, Tuple>;
    fn into_iter(self) -> Self::IntoIter {
        self.iter()
    }
}

impl PartialEq for TupleBatch {
    fn eq(&self, other: &TupleBatch) -> bool {
        self.as_slice() == other.as_slice()
    }
}

/// Field types for schema declaration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FieldType {
    Int,
    Float,
    Str,
}

/// A named, typed schema.
#[derive(Clone, Debug, PartialEq)]
pub struct Schema {
    pub fields: Vec<(String, FieldType)>,
}

impl Schema {
    pub fn new(fields: &[(&str, FieldType)]) -> Schema {
        Schema {
            fields: fields
                .iter()
                .map(|(n, t)| (n.to_string(), *t))
                .collect(),
        }
    }

    /// Position of a field by name.
    pub fn index_of(&self, name: &str) -> Option<usize> {
        self.fields.iter().position(|(n, _)| n == name)
    }

    /// Schema of a join output (concatenation; right-side names prefixed
    /// on collision).
    pub fn concat(&self, other: &Schema) -> Schema {
        let mut fields = self.fields.clone();
        for (n, t) in &other.fields {
            let name = if self.index_of(n).is_some() {
                format!("r_{n}")
            } else {
                n.clone()
            };
            fields.push((name, *t));
        }
        Schema { fields }
    }

    pub fn arity(&self) -> usize {
        self.fields.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stable_hash_is_stable() {
        let v = Value::str("california");
        assert_eq!(v.stable_hash(), Value::str("california").stable_hash());
        assert_ne!(v.stable_hash(), Value::str("arizona").stable_hash());
        assert_ne!(Value::Int(1).stable_hash(), Value::Int(2).stable_hash());
        // Int and Float with same numeric value hash differently (typed).
        assert_ne!(
            Value::Int(1).stable_hash(),
            Value::Float(1.0).stable_hash()
        );
    }

    #[test]
    fn stable_hash_normalizes_negative_zero() {
        // -0.0 and 0.0 are PartialEq-equal, so they must hash-route
        // to the same worker at every parallelism (regression: the FNV
        // path hashed the raw sign bit and split the key).
        assert_eq!(Value::Float(-0.0), Value::Float(0.0));
        assert_eq!(
            Value::Float(-0.0).stable_hash(),
            Value::Float(0.0).stable_hash()
        );
        for n in 2u64..10 {
            assert_eq!(
                Value::Float(-0.0).stable_hash() % n,
                Value::Float(0.0).stable_hash() % n
            );
        }
        // Other negative floats keep their sign.
        assert_ne!(
            Value::Float(-1.5).stable_hash(),
            Value::Float(1.5).stable_hash()
        );
    }

    #[test]
    fn stable_hash_strings_word_at_a_time_boundaries() {
        // Lengths around the 8-byte word boundary must stay distinct
        // (tail-padding must not alias shorter strings).
        let cases = ["", "a", "abcdefg", "abcdefgh", "abcdefghi", "abcdefgh\0"];
        for (i, a) in cases.iter().enumerate() {
            for b in cases.iter().skip(i + 1) {
                assert_ne!(
                    Value::str(a).stable_hash(),
                    Value::str(b).stable_hash(),
                    "{a:?} vs {b:?}"
                );
            }
        }
    }

    #[test]
    fn value_order_total() {
        use std::cmp::Ordering::*;
        assert_eq!(value_cmp(&Value::Null, &Value::Int(0)), Less);
        assert_eq!(value_cmp(&Value::Int(2), &Value::Float(2.5)), Less);
        assert_eq!(value_cmp(&Value::Float(3.0), &Value::Int(3)), Equal);
        assert_eq!(value_cmp(&Value::str("b"), &Value::str("a")), Greater);
        assert_eq!(value_cmp(&Value::str("a"), &Value::Int(9)), Greater);
    }

    #[test]
    fn tuple_concat() {
        let a = Tuple::new(vec![Value::Int(1)]);
        let b = Tuple::new(vec![Value::str("x"), Value::Float(2.0)]);
        let c = a.concat(&b);
        assert_eq!(c.arity(), 3);
        assert_eq!(c.get(1).as_str(), Some("x"));
    }

    #[test]
    fn schema_lookup_and_concat() {
        let s1 = Schema::new(&[("id", FieldType::Int), ("loc", FieldType::Str)]);
        let s2 = Schema::new(&[("id", FieldType::Int), ("val", FieldType::Float)]);
        assert_eq!(s1.index_of("loc"), Some(1));
        let j = s1.concat(&s2);
        assert_eq!(j.arity(), 4);
        assert_eq!(j.index_of("r_id"), Some(2));
        assert_eq!(j.index_of("val"), Some(3));
    }

    #[test]
    fn byte_size_counts_strings() {
        let t = Tuple::new(vec![Value::str("abcd"), Value::Int(5)]);
        assert_eq!(t.byte_size(), 8 + (16 + 4) + 8);
    }

    fn int_batch(n: i64) -> TupleBatch {
        (0..n).map(|i| Tuple::new(vec![Value::Int(i)])).collect()
    }

    #[test]
    fn batch_clone_and_slice_share_storage() {
        let b = int_batch(10);
        let c = b.clone();
        assert!(TupleBatch::ptr_eq(&b, &c));
        let s = b.slice(2, 7);
        assert!(TupleBatch::ptr_eq(&b, &s));
        assert_eq!(s.len(), 5);
        assert_eq!(s.get(0).get(0).as_int(), Some(2));
        // Slicing a slice stays relative to the view, not the storage.
        let s2 = s.slice_from(3);
        assert_eq!(s2.len(), 2);
        assert_eq!(s2.get(0).get(0).as_int(), Some(5));
        assert!(TupleBatch::ptr_eq(&b, &s2));
    }

    #[test]
    fn batch_equality_is_by_content() {
        let a = int_batch(4);
        let b = int_batch(4);
        assert!(!TupleBatch::ptr_eq(&a, &b));
        assert_eq!(a, b);
        assert_ne!(a, a.slice(0, 3));
    }

    #[test]
    fn batch_empty_and_iter() {
        assert!(TupleBatch::empty().is_empty());
        assert_eq!(TupleBatch::default().len(), 0);
        let b = int_batch(3);
        let vals: Vec<i64> = b.iter().map(|t| t.get(0).as_int().unwrap()).collect();
        assert_eq!(vals, vec![0, 1, 2]);
        assert_eq!(b.to_vec().len(), 3);
        assert_eq!(b.byte_size(), 3 * 16);
    }

    #[test]
    fn columnar_batch_is_a_shared_lazy_view() {
        let rows: Vec<Tuple> = (0..6)
            .map(|i| Tuple::new(vec![Value::Int(i), Value::str("k")]))
            .collect();
        let set = ColumnSet::from_rows(&rows).unwrap();
        let b = TupleBatch::from_columns(set);
        assert!(b.has_columns());
        assert_eq!(b.len(), 6);
        // byte_size works straight off the columns, before any rows
        // exist, and matches the row accounting.
        let want: usize = rows.iter().map(Tuple::byte_size).sum();
        assert_eq!(b.byte_size(), want);
        // Clones and slices share storage and keep the columnar view.
        let s = b.slice(2, 5);
        assert!(TupleBatch::ptr_eq(&b, &s));
        let cv = s.columns().unwrap();
        assert_eq!((cv.start, cv.end, cv.len()), (2, 5, 3));
        // Row access lazily transposes; the values round-trip.
        assert_eq!(s.get(0), &rows[2]);
        assert_eq!(b.as_slice(), &rows[..]);
        assert_eq!(b, TupleBatch::new(rows));
    }

    #[test]
    fn row_batch_transposes_on_demand() {
        let b = int_batch(5);
        assert!(!b.has_columns());
        assert!(b.columns().is_none());
        let s = b.slice(1, 4);
        let cv = s.ensure_columns().unwrap();
        assert_eq!((cv.start, cv.end), (1, 4));
        let mut hashes = Vec::new();
        cv.set.cols[0].hash_range(cv.start, cv.end, &mut hashes);
        let want: Vec<u64> =
            s.iter().map(|t| t.get(0).stable_hash()).collect();
        assert_eq!(hashes, want);
        // The transpose is cached on the shared storage: the original
        // view sees it too.
        assert!(b.has_columns());
    }

    #[test]
    fn ragged_batch_refuses_columns() {
        let b = TupleBatch::new(vec![
            Tuple::new(vec![Value::Int(1)]),
            Tuple::new(vec![Value::Int(1), Value::Int(2)]),
        ]);
        assert!(b.ensure_columns().is_none());
        assert!(!b.has_columns());
        assert_eq!(b.len(), 2);
    }
}

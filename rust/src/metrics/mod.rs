//! Runtime metrics: counters, percentile summaries, and time-series
//! recorders used by the experiment harnesses (candlestick charts like
//! Figs. 2.10/2.11/3.23 need p1/p25/p50/p75/p99; the Reshape result
//! plots need timestamped series).

use std::time::Instant;

/// Cardinality-estimation q-error: `max(est/obs, obs/est)`, the
/// standard factor-off metric (1.0 = exact). Degenerate inputs: both
/// sides non-positive is a perfect estimate (1.0 — predicting an empty
/// output that was empty); exactly one side non-positive is infinitely
/// wrong (infinity). Maestro's re-planner records one per operator
/// when it pins observed cardinalities over plan-time guesses.
pub fn q_error(est: f64, obs: f64) -> f64 {
    if est <= 0.0 && obs <= 0.0 {
        1.0
    } else if est <= 0.0 || obs <= 0.0 {
        f64::INFINITY
    } else {
        (est / obs).max(obs / est)
    }
}

/// Percentile summary over a set of f64 samples.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    samples: Vec<f64>,
}

impl Summary {
    pub fn new() -> Summary {
        Summary::default()
    }

    pub fn record(&mut self, v: f64) {
        self.samples.push(v);
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Percentile via nearest-rank on the sorted samples; `p` in [0,100].
    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        let mut sorted = self.samples.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = ((p / 100.0) * (sorted.len() as f64 - 1.0)).round() as usize;
        sorted[rank.min(sorted.len() - 1)]
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::NAN, f64::max)
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::NAN, f64::min)
    }

    /// The five candlestick points the paper plots: p1, p25, p50, p75, p99.
    pub fn candlestick(&self) -> [f64; 5] {
        [
            self.percentile(1.0),
            self.percentile(25.0),
            self.percentile(50.0),
            self.percentile(75.0),
            self.percentile(99.0),
        ]
    }
}

/// A timestamped series of (seconds-since-start, value) observations.
#[derive(Debug)]
pub struct Timeline {
    start: Instant,
    pub points: Vec<(f64, f64)>,
}

impl Default for Timeline {
    fn default() -> Self {
        Timeline::new()
    }
}

impl Timeline {
    pub fn new() -> Timeline {
        Timeline { start: Instant::now(), points: Vec::new() }
    }

    pub fn record(&mut self, value: f64) {
        self.points
            .push((self.start.elapsed().as_secs_f64(), value));
    }

    pub fn record_at(&mut self, t: f64, value: f64) {
        self.points.push((t, value));
    }

    /// Earliest time at which the value enters (and stays within)
    /// `±tol` of `target` — used for "time to reach the actual ratio"
    /// readings (Figs. 3.16–3.19).
    pub fn time_to_converge(&self, target: f64, tol: f64) -> Option<f64> {
        let mut candidate: Option<f64> = None;
        for &(t, v) in &self.points {
            if (v - target).abs() <= tol {
                candidate.get_or_insert(t);
            } else {
                candidate = None;
            }
        }
        candidate
    }
}

/// Counters for the supervision layer (panic containment + heartbeat
/// failure detection + automatic replay-based recovery): how failures
/// were detected, how fast, how long recovery took, and the automatic
/// checkpoint cadence/sizes. Accumulated by the coordinator and
/// surfaced through `ExecSummary::supervision`; the `faults` bench
/// section reads these.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SupervisionStats {
    /// Failures declared because a worker thread panicked
    /// (`WorkerFailed` containment events).
    pub crashes_detected: u64,
    /// Failures declared because a worker's heartbeat went silent for
    /// `heartbeat_timeout_ms` (stall, not crash).
    pub stalls_detected: u64,
    /// Worst observed failure→declaration latency in ms (panic instant
    /// to coordinator declaration; stalls count from the last
    /// heartbeat observation).
    pub detection_ms_max: f64,
    /// Completed automatic recovery cycles (teardown → restore →
    /// replay → resume).
    pub recoveries: u64,
    /// Total / worst wall-clock spent inside recovery cycles, ms
    /// (including backoff sleeps).
    pub recovery_ms_total: f64,
    pub recovery_ms_max: f64,
    /// Whether the run aborted with retries exhausted.
    pub retries_exhausted: bool,
    /// Automatic (timer-driven) checkpoints completed.
    pub auto_checkpoints: u64,
    /// State size (tuples) of the latest completed checkpoint —
    /// automatic or manual.
    pub last_checkpoint_tuples: u64,
    /// Mean observed interval between completed automatic checkpoints,
    /// ms (NaN until two have completed).
    pub checkpoint_interval_ms_observed: f64,
}

impl SupervisionStats {
    /// Total declared failures, regardless of detection path.
    pub fn failures_detected(&self) -> u64 {
        self.crashes_detected + self.stalls_detected
    }

    /// Fold one detection latency observation into the max.
    pub fn observe_detection_ms(&mut self, ms: f64) {
        if ms.is_finite() && ms > self.detection_ms_max {
            self.detection_ms_max = ms;
        }
    }

    /// Fold one completed recovery cycle's duration into the counters.
    pub fn observe_recovery_ms(&mut self, ms: f64) {
        self.recoveries += 1;
        self.recovery_ms_total += ms;
        if ms > self.recovery_ms_max {
            self.recovery_ms_max = ms;
        }
    }
}

/// Counters for the out-of-core layer (memory-budget accounting +
/// operator/`MatStore` spilling — see `engine::spill` and the
/// "Out-of-core execution" section of `docs/ARCHITECTURE.md`).
/// Accumulated by the shared per-execution `SpillCtx` and surfaced
/// through `ExecSummary::spill`; the `spill` bench section and the
/// out-of-core equivalence suite read these.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SpillStats {
    /// Bytes written to spill files (operator partitions, sort runs,
    /// `MatStore` chunks), including frame headers.
    pub bytes_spilled: u64,
    /// Bytes read back from spill files.
    pub bytes_read_back: u64,
    /// Hash partitions evicted to disk (join build / group-by),
    /// counting each recursion-level eviction separately.
    pub partitions_spilled: u64,
    /// Spill files created over the execution (never deleted mid-run;
    /// the whole directory is reclaimed at teardown).
    pub spill_files_created: u64,
    /// Deepest recursive re-partitioning reached (0 = no recursion).
    pub max_recursion_depth: u64,
    /// The configured `Config::memory_budget_bytes` (0 = unbounded).
    pub budget_limit: u64,
    /// High-water mark of bytes charged against the budget (tracked
    /// even when unbounded — the equivalence suite derives its
    /// constrained budgets from an unbounded run's high water).
    pub budget_high_water: u64,
    /// Wall time spent encoding + writing spill frames. Together with
    /// `bytes_spilled` this is the observed spill-write bandwidth the
    /// cost model calibrates from (`CostParams::calibrate_spill`).
    pub spill_write_ns: u64,
    /// Wall time spent reading + decoding spill frames (read-back
    /// bandwidth counterpart).
    pub spill_read_ns: u64,
}

/// Counters for the multi-tenant serving layer: admission outcomes,
/// completions, cache effectiveness, preemption activity, and a
/// point-in-time view of the worker budget. Snapshotted by
/// `EngineService::stats`; the `service` bench section reads these.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ServiceStats {
    /// Submissions received (admitted + rejected + cache hits).
    pub submitted: u64,
    /// Submissions that entered the queue.
    pub admitted: u64,
    /// Rejections: global queue at capacity.
    pub rejected_queue_full: u64,
    /// Rejections: tenant over `max_queued`.
    pub rejected_quota: u64,
    /// Rejections: minimum footprint exceeds the whole budget.
    pub rejected_too_large: u64,
    /// Jobs finished cleanly (including cache hits).
    pub completed: u64,
    /// Jobs that terminated with a structured engine error.
    pub failed: u64,
    /// Jobs cancelled by the caller or by shutdown.
    pub cancelled: u64,
    /// Result-cache hits / misses among cache-opted submissions.
    pub cache_hits: u64,
    pub cache_misses: u64,
    /// Batch jobs pause-fenced to make room for interactive ones.
    pub preemptions: u64,
    /// Preempted jobs resumed after budget freed.
    pub resumes: u64,
    /// Global worker budget (0 = unbounded).
    pub capacity: usize,
    /// Runnable workers currently charged to the ledger.
    pub workers_in_use: usize,
    /// High-water mark of `workers_in_use` — never exceeds `capacity`.
    pub peak_workers: usize,
    /// Submissions waiting in the admission queue right now.
    pub queued_now: usize,
    /// Jobs running (or preempted-but-live) right now.
    pub running_now: usize,
}

/// The paper's load-balancing ratio (§3.7.4): min(load_S, load_H) /
/// max(load_S, load_H), averaged over periodic observations.
#[derive(Clone, Debug, Default)]
pub struct LoadBalanceRatio {
    ratios: Vec<f64>,
}

impl LoadBalanceRatio {
    pub fn observe(&mut self, a: f64, b: f64) {
        let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
        if hi > 0.0 {
            self.ratios.push(lo / hi);
        }
    }

    /// Average load-balancing ratio over the execution.
    pub fn average(&self) -> f64 {
        if self.ratios.is_empty() {
            return f64::NAN;
        }
        self.ratios.iter().sum::<f64>() / self.ratios.len() as f64
    }

    pub fn observations(&self) -> usize {
        self.ratios.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn q_error_symmetric_and_exact_at_one() {
        assert_eq!(q_error(100.0, 100.0), 1.0);
        assert_eq!(q_error(10.0, 1000.0), 100.0);
        assert_eq!(q_error(1000.0, 10.0), 100.0);
        assert_eq!(q_error(0.0, 0.0), 1.0);
        assert_eq!(q_error(0.0, 5.0), f64::INFINITY);
        assert_eq!(q_error(5.0, 0.0), f64::INFINITY);
    }

    #[test]
    fn percentiles_ordered() {
        let mut s = Summary::new();
        for i in 0..100 {
            s.record(i as f64);
        }
        let c = s.candlestick();
        assert!(c.windows(2).all(|w| w[0] <= w[1]), "{c:?}");
        assert_eq!(c[2], 50.0);
    }

    #[test]
    fn empty_summary_nan() {
        assert!(Summary::new().percentile(50.0).is_nan());
    }

    #[test]
    fn mean_simple() {
        let mut s = Summary::new();
        s.record(2.0);
        s.record(4.0);
        assert_eq!(s.mean(), 3.0);
    }

    #[test]
    fn converge_requires_staying() {
        let mut tl = Timeline::new();
        tl.record_at(0.0, 10.0);
        tl.record_at(1.0, 5.0); // touches target…
        tl.record_at(2.0, 10.0); // …but leaves
        tl.record_at(3.0, 5.2);
        tl.record_at(4.0, 4.9);
        assert_eq!(tl.time_to_converge(5.0, 0.5), Some(3.0));
    }

    #[test]
    fn supervision_stats_fold() {
        let mut s = SupervisionStats::default();
        s.crashes_detected += 1;
        s.stalls_detected += 1;
        s.observe_detection_ms(3.5);
        s.observe_detection_ms(1.0); // must not lower the max
        s.observe_recovery_ms(10.0);
        s.observe_recovery_ms(30.0);
        assert_eq!(s.failures_detected(), 2);
        assert_eq!(s.detection_ms_max, 3.5);
        assert_eq!(s.recoveries, 2);
        assert_eq!(s.recovery_ms_total, 40.0);
        assert_eq!(s.recovery_ms_max, 30.0);
        assert!(!s.retries_exhausted);
    }

    #[test]
    fn lbr_symmetric_and_bounded() {
        let mut r = LoadBalanceRatio::default();
        r.observe(50.0, 100.0);
        r.observe(100.0, 50.0);
        assert!((r.average() - 0.5).abs() < 1e-9);
        r.observe(100.0, 100.0);
        assert!(r.average() <= 1.0);
    }
}

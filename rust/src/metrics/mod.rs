//! Runtime metrics: counters, percentile summaries, and time-series
//! recorders used by the experiment harnesses (candlestick charts like
//! Figs. 2.10/2.11/3.23 need p1/p25/p50/p75/p99; the Reshape result
//! plots need timestamped series).

use std::time::Instant;

/// Cardinality-estimation q-error: `max(est/obs, obs/est)`, the
/// standard factor-off metric (1.0 = exact). Degenerate inputs: both
/// sides non-positive is a perfect estimate (1.0 — predicting an empty
/// output that was empty); exactly one side non-positive is infinitely
/// wrong (infinity). Maestro's re-planner records one per operator
/// when it pins observed cardinalities over plan-time guesses.
pub fn q_error(est: f64, obs: f64) -> f64 {
    if est <= 0.0 && obs <= 0.0 {
        1.0
    } else if est <= 0.0 || obs <= 0.0 {
        f64::INFINITY
    } else {
        (est / obs).max(obs / est)
    }
}

/// Percentile summary over a set of f64 samples.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    samples: Vec<f64>,
}

impl Summary {
    pub fn new() -> Summary {
        Summary::default()
    }

    pub fn record(&mut self, v: f64) {
        self.samples.push(v);
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Percentile via nearest-rank on the sorted samples; `p` in [0,100].
    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        let mut sorted = self.samples.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = ((p / 100.0) * (sorted.len() as f64 - 1.0)).round() as usize;
        sorted[rank.min(sorted.len() - 1)]
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::NAN, f64::max)
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::NAN, f64::min)
    }

    /// The five candlestick points the paper plots: p1, p25, p50, p75, p99.
    pub fn candlestick(&self) -> [f64; 5] {
        [
            self.percentile(1.0),
            self.percentile(25.0),
            self.percentile(50.0),
            self.percentile(75.0),
            self.percentile(99.0),
        ]
    }
}

/// A timestamped series of (seconds-since-start, value) observations.
#[derive(Debug)]
pub struct Timeline {
    start: Instant,
    pub points: Vec<(f64, f64)>,
}

impl Default for Timeline {
    fn default() -> Self {
        Timeline::new()
    }
}

impl Timeline {
    pub fn new() -> Timeline {
        Timeline { start: Instant::now(), points: Vec::new() }
    }

    pub fn record(&mut self, value: f64) {
        self.points
            .push((self.start.elapsed().as_secs_f64(), value));
    }

    pub fn record_at(&mut self, t: f64, value: f64) {
        self.points.push((t, value));
    }

    /// Earliest time at which the value enters (and stays within)
    /// `±tol` of `target` — used for "time to reach the actual ratio"
    /// readings (Figs. 3.16–3.19).
    pub fn time_to_converge(&self, target: f64, tol: f64) -> Option<f64> {
        let mut candidate: Option<f64> = None;
        for &(t, v) in &self.points {
            if (v - target).abs() <= tol {
                candidate.get_or_insert(t);
            } else {
                candidate = None;
            }
        }
        candidate
    }
}

/// The paper's load-balancing ratio (§3.7.4): min(load_S, load_H) /
/// max(load_S, load_H), averaged over periodic observations.
#[derive(Clone, Debug, Default)]
pub struct LoadBalanceRatio {
    ratios: Vec<f64>,
}

impl LoadBalanceRatio {
    pub fn observe(&mut self, a: f64, b: f64) {
        let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
        if hi > 0.0 {
            self.ratios.push(lo / hi);
        }
    }

    /// Average load-balancing ratio over the execution.
    pub fn average(&self) -> f64 {
        if self.ratios.is_empty() {
            return f64::NAN;
        }
        self.ratios.iter().sum::<f64>() / self.ratios.len() as f64
    }

    pub fn observations(&self) -> usize {
        self.ratios.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn q_error_symmetric_and_exact_at_one() {
        assert_eq!(q_error(100.0, 100.0), 1.0);
        assert_eq!(q_error(10.0, 1000.0), 100.0);
        assert_eq!(q_error(1000.0, 10.0), 100.0);
        assert_eq!(q_error(0.0, 0.0), 1.0);
        assert_eq!(q_error(0.0, 5.0), f64::INFINITY);
        assert_eq!(q_error(5.0, 0.0), f64::INFINITY);
    }

    #[test]
    fn percentiles_ordered() {
        let mut s = Summary::new();
        for i in 0..100 {
            s.record(i as f64);
        }
        let c = s.candlestick();
        assert!(c.windows(2).all(|w| w[0] <= w[1]), "{c:?}");
        assert_eq!(c[2], 50.0);
    }

    #[test]
    fn empty_summary_nan() {
        assert!(Summary::new().percentile(50.0).is_nan());
    }

    #[test]
    fn mean_simple() {
        let mut s = Summary::new();
        s.record(2.0);
        s.record(4.0);
        assert_eq!(s.mean(), 3.0);
    }

    #[test]
    fn converge_requires_staying() {
        let mut tl = Timeline::new();
        tl.record_at(0.0, 10.0);
        tl.record_at(1.0, 5.0); // touches target…
        tl.record_at(2.0, 10.0); // …but leaves
        tl.record_at(3.0, 5.2);
        tl.record_at(4.0, 4.9);
        assert_eq!(tl.time_to_converge(5.0, 0.5), Some(3.0));
    }

    #[test]
    fn lbr_symmetric_and_bounded() {
        let mut r = LoadBalanceRatio::default();
        r.observe(50.0, 100.0);
        r.observe(100.0, 50.0);
        assert!((r.average() - 0.5).abs() < 1e-9);
        r.observe(100.0, 100.0);
        assert!(r.average() <= 1.0);
    }
}

//! Reusable workflow definitions — the paper's experiment workflows,
//! shared by the CLI, the examples, and the bench harnesses.
//!
//! | builder | paper workflow |
//! |---|---|
//! | [`tpch_q1`] | Ch. 2 W1 (TPC-H Q1-style: scan→filter→group-by→sort) |
//! | [`tpch_q13`] | Ch. 2 W2 (Q13-style: customer ⋈ orders → counts) |
//! | [`orders_sort`] | Ch. 3 W3 (range-partitioned sort on totalprice) |
//! | [`tweet_join`] | Ch. 3 W1 (tweets ⋈ slang on location, CA-skewed) |
//! | [`dsb_q18`] | Ch. 3 W2 (web_sales ⋈ item/date/customer, two skewed joins) |
//! | [`synthetic_join`] | Ch. 3 W4 (distribution-shift join) |

use crate::engine::partitioner::equal_width_bounds;
use crate::engine::{OpSpec, PartitionScheme, Workflow};
use crate::operators::basic::{Cmp, Filter};
use crate::operators::{
    AggKind, CollectSink, CountByKeySink, GroupByFinal, GroupByPartial, HashJoin,
    SinkHandle, SortMerge, SortWorker,
};
use crate::tuple::{Tuple, Value};
use crate::workloads::dsb::{self, WebSalesSource};
use crate::workloads::synthetic::{self, ShiftingSource};
use crate::workloads::tpch::{self, CustomerSource, LineitemSource, OrdersSource};
use crate::workloads::tweets::{self, TweetSource};
use crate::workloads::{TupleSource, VecSource};
use std::sync::Arc;

/// Handles returned with each workflow: the sink handle plus the index
/// of the "interesting" operator (filter/join/sort — what experiments
/// instrument).
pub struct Flow {
    pub workflow: Workflow,
    pub sink: SinkHandle,
    /// Operator the experiment focuses on (breakpoints, skew…).
    pub focus: usize,
    /// The sink operator index (Maestro result operator).
    pub sink_op: usize,
}

/// Ch. 2 W1 ≈ TPC-H Q1: lineitem → filter(shipdate) → group-by → sort.
pub fn tpch_q1(sf: f64, workers: usize) -> Flow {
    let mut w = Workflow::new();
    let scan = w.add(OpSpec::source("scan_lineitem", workers, move |idx, parts| {
        Box::new(LineitemSource::new(sf, parts, idx, 0x71C8)) as Box<dyn TupleSource>
    }));
    let filter = w.add(OpSpec::unary("filter", workers, PartitionScheme::RoundRobin, |_, _| {
        Box::new(Filter::new(tpch::L_SHIPDATE, Cmp::Le, Value::Int(19980902)))
    }));
    let partial = w.add(OpSpec::unary(
        "gb_partial",
        workers,
        PartitionScheme::RoundRobin,
        |_, _| {
            Box::new(GroupByPartial::new(
                tpch::L_RETURNFLAG,
                tpch::L_QUANTITY,
                AggKind::Sum,
            ))
        },
    ));
    let fin = w.add(
        OpSpec::unary("gb_final", workers, PartitionScheme::Hash { key: 0 }, |_, _| {
            Box::new(GroupByFinal::new(AggKind::Sum))
        })
        .with_blocking(vec![0]),
    );
    let merge = w.add(
        OpSpec::unary("sort", 1, PartitionScheme::RoundRobin, |_, _| {
            Box::new(SortMerge::new(1))
        })
        .with_blocking(vec![0]),
    );
    let sink_handle = SinkHandle::new(0);
    let h = sink_handle.clone();
    let sink = w.add(OpSpec::unary("sink", 1, PartitionScheme::RoundRobin, move |_, _| {
        Box::new(CollectSink::new(h.clone()))
    }));
    w.connect(scan, filter, 0);
    w.connect(filter, partial, 0);
    w.connect(partial, fin, 0);
    w.connect(fin, merge, 0);
    w.connect(merge, sink, 0);
    Flow { workflow: w, sink: sink_handle, focus: filter, sink_op: sink }
}

/// Ch. 2 W2 ≈ TPC-H Q13: customer ⋈ orders → count per customer.
pub fn tpch_q13(sf: f64, workers: usize) -> Flow {
    let mut w = Workflow::new();
    let cust = w.add(OpSpec::source("scan_customer", workers, move |idx, parts| {
        Box::new(CustomerSource::new(sf, parts, idx, 0xC057)) as Box<dyn TupleSource>
    }));
    let orders = w.add(OpSpec::source("scan_orders", workers, move |idx, parts| {
        Box::new(OrdersSource::new(sf, parts, idx, 0x08D3)) as Box<dyn TupleSource>
    }));
    let join = w.add(OpSpec::binary(
        "join",
        workers,
        [
            PartitionScheme::Hash { key: tpch::C_CUSTKEY },
            PartitionScheme::Hash { key: tpch::O_CUSTKEY },
        ],
        vec![0],
        |_, _| Box::new(HashJoin::new(tpch::C_CUSTKEY, tpch::O_CUSTKEY)),
    ));
    let partial = w.add(OpSpec::unary(
        "gb_partial",
        workers,
        PartitionScheme::RoundRobin,
        |_, _| Box::new(GroupByPartial::new(0, 0, AggKind::Count)),
    ));
    let fin = w.add(
        OpSpec::unary("gb_final", workers, PartitionScheme::Hash { key: 0 }, |_, _| {
            Box::new(GroupByFinal::new(AggKind::Count))
        })
        .with_blocking(vec![0]),
    );
    let sink_handle = SinkHandle::new(0);
    let h = sink_handle.clone();
    let sink = w.add(OpSpec::unary("sink", 1, PartitionScheme::RoundRobin, move |_, _| {
        Box::new(CollectSink::new(h.clone()))
    }));
    w.connect(cust, join, 0);
    w.connect(orders, join, 1);
    w.connect(join, partial, 0);
    w.connect(partial, fin, 0);
    w.connect(fin, sink, 0);
    Flow { workflow: w, sink: sink_handle, focus: join, sink_op: sink }
}

/// Ch. 3 W3: orders → filter(status) → range-partitioned sort → merge.
pub fn orders_sort(sf: f64, workers: usize) -> Flow {
    orders_sort_costed(sf, workers, 0)
}

/// [`orders_sort`] with an artificial per-tuple sort cost so the sort
/// workers are the bottleneck.
pub fn orders_sort_costed(sf: f64, workers: usize, cost_ns: u64) -> Flow {
    let bounds = equal_width_bounds(1_000.0, 550_000.0, workers);
    let b2 = bounds.clone();
    let mut w = Workflow::new();
    let scan = w.add(OpSpec::source("scan_orders", 2, move |idx, parts| {
        Box::new(OrdersSource::new(sf, parts, idx, 0x50F7)) as Box<dyn TupleSource>
    }));
    let filter = w.add(OpSpec::unary("filter", 2, PartitionScheme::RoundRobin, |_, _| {
        Box::new(Filter::new(tpch::O_ORDERSTATUS, Cmp::Ne, Value::str("P")))
    }));
    let sort = w.add(
        OpSpec::unary(
            "sort",
            workers,
            PartitionScheme::Range { key: tpch::O_TOTALPRICE, bounds },
            move |idx, _| {
                Box::new(
                    SortWorker::new(tpch::O_TOTALPRICE, idx as u64, b2.clone())
                        .with_cost(cost_ns),
                )
            },
        )
        .with_blocking(vec![0])
        .with_scatter_merge(),
    );
    let merge = w.add(
        OpSpec::unary("merge", 1, PartitionScheme::RoundRobin, |_, _| {
            Box::new(SortMerge::new(tpch::O_TOTALPRICE))
        })
        .with_blocking(vec![0]),
    );
    let sink_handle = SinkHandle::new(0);
    let h = sink_handle.clone();
    let sink = w.add(OpSpec::unary("sink", 1, PartitionScheme::RoundRobin, move |_, _| {
        Box::new(CollectSink::new(h.clone()))
    }));
    w.connect(scan, filter, 0);
    w.connect(filter, sort, 0);
    w.connect(sort, merge, 0);
    w.connect(merge, sink, 0);
    Flow { workflow: w, sink: sink_handle, focus: sort, sink_op: sink }
}

/// Ch. 3 W1: tweets ⋈ slang on location (CA-skewed), per-location
/// counts at the sink. The sink counts by the tweet location field
/// (join output field 2 + F_LOCATION).
pub fn tweet_join(total: usize, workers: usize, seed: u64) -> Flow {
    tweet_join_costed(total, workers, seed, 0)
}

/// [`tweet_join`] with an artificial per-probe-tuple join cost — used
/// by the skew experiments, which assume the join is the bottleneck
/// (§3.3.1).
pub fn tweet_join_costed(total: usize, workers: usize, seed: u64, probe_cost_ns: u64) -> Flow {
    let mut w = Workflow::new();
    let slang: Arc<Vec<Tuple>> = Arc::new(tweets::slang_table());
    let s2 = slang.clone();
    let build_scan = w.add(OpSpec::source("slang_scan", 1, move |idx, parts| {
        let rows: Vec<Tuple> = s2
            .iter()
            .enumerate()
            .filter(|(i, _)| i % parts == idx)
            .map(|(_, t)| t.clone())
            .collect();
        Box::new(VecSource::new(rows)) as Box<dyn TupleSource>
    }));
    let tweet_scan = w.add(OpSpec::source("tweet_scan", 2, move |idx, parts| {
        Box::new(TweetSource::new(total, parts, idx, seed)) as Box<dyn TupleSource>
    }));
    let join = w.add(OpSpec::binary(
        "join",
        workers,
        [
            PartitionScheme::Hash { key: 0 },
            PartitionScheme::Hash { key: tweets::F_LOCATION },
        ],
        vec![0],
        move |_, _| {
            Box::new(HashJoin::new(0, tweets::F_LOCATION).with_probe_cost(probe_cost_ns))
        },
    ));
    let sink_handle = SinkHandle::new(tweets::NUM_STATES);
    let h = sink_handle.clone();
    let sink = w.add(OpSpec::unary("sink", 1, PartitionScheme::RoundRobin, move |_, _| {
        Box::new(CountByKeySink::new(h.clone(), 2 + tweets::F_LOCATION))
    }));
    w.connect(build_scan, join, 0);
    w.connect(tweet_scan, join, 1);
    w.connect(join, sink, 0);
    Flow { workflow: w, sink: sink_handle, focus: join, sink_op: sink }
}

/// Ch. 3 W2 ≈ TPC-DS Q18 on DSB data: web_sales joined with item
/// (highly skewed), date (moderately skewed) and customer dims, then
/// count per category. Returns (flow, item-join idx, date-join idx).
pub fn dsb_q18(rows: usize, workers: usize, seed: u64) -> (Flow, usize, usize) {
    dsb_q18_costed(rows, workers, seed, 0)
}

/// [`dsb_q18`] with an artificial per-probe-tuple cost on both joins.
pub fn dsb_q18_costed(
    rows: usize,
    workers: usize,
    seed: u64,
    probe_cost_ns: u64,
) -> (Flow, usize, usize) {
    let mut w = Workflow::new();
    let sales = w.add(OpSpec::source("scan_web_sales", 2, move |idx, parts| {
        Box::new(WebSalesSource::new(rows, parts, idx, seed, Default::default()))
            as Box<dyn TupleSource>
    }));
    let item_dim = w.add(OpSpec::source("scan_item", 1, |idx, parts| {
        let rows: Vec<Tuple> = dsb::item_table()
            .into_iter()
            .enumerate()
            .filter(|(i, _)| i % parts == idx)
            .map(|(_, t)| t)
            .collect();
        Box::new(VecSource::new(rows)) as Box<dyn TupleSource>
    }));
    let date_dim = w.add(OpSpec::source("scan_date", 1, |idx, parts| {
        let rows: Vec<Tuple> = dsb::date_table()
            .into_iter()
            .enumerate()
            .filter(|(i, _)| i % parts == idx)
            .map(|(_, t)| t)
            .collect();
        Box::new(VecSource::new(rows)) as Box<dyn TupleSource>
    }));
    // item join: sales.item_id = item.item_id (HIGH skew on probe).
    let j_item = w.add(OpSpec::binary(
        "join_item",
        workers,
        [
            PartitionScheme::Hash { key: 0 },
            PartitionScheme::Hash { key: dsb::WS_ITEM },
        ],
        vec![0],
        move |_, _| Box::new(HashJoin::new(0, dsb::WS_ITEM).with_probe_cost(probe_cost_ns)),
    ));
    // join output: item(2) ++ sales(5) → date_id at 2 + WS_DATE.
    let date_key = 2 + dsb::WS_DATE;
    let j_date = w.add(OpSpec::binary(
        "join_date",
        workers,
        [
            PartitionScheme::Hash { key: 0 },
            PartitionScheme::Hash { key: date_key },
        ],
        vec![0],
        move |_, _| Box::new(HashJoin::new(0, date_key).with_probe_cost(probe_cost_ns)),
    ));
    // Category counts. Field layout after join_date: date(2: date_id,
    // year) ++ join_item output(7: item_id, category, sales…) → the
    // item category sits at index 3.
    let partial = w.add(OpSpec::unary(
        "gb_partial",
        workers,
        PartitionScheme::RoundRobin,
        |_, _| Box::new(GroupByPartial::new(3, 0, AggKind::Count)),
    ));
    let fin = w.add(
        OpSpec::unary("gb_final", workers, PartitionScheme::Hash { key: 0 }, |_, _| {
            Box::new(GroupByFinal::new(AggKind::Count))
        })
        .with_blocking(vec![0]),
    );
    let sink_handle = SinkHandle::new(0);
    let h = sink_handle.clone();
    let sink = w.add(OpSpec::unary("sink", 1, PartitionScheme::RoundRobin, move |_, _| {
        Box::new(CollectSink::new(h.clone()))
    }));
    w.connect(item_dim, j_item, 0);
    w.connect(sales, j_item, 1);
    w.connect(date_dim, j_date, 0);
    w.connect(j_item, j_date, 1);
    w.connect(j_date, partial, 0);
    w.connect(partial, fin, 0);
    w.connect(fin, sink, 0);
    (
        Flow { workflow: w, sink: sink_handle, focus: j_item, sink_op: sink },
        j_item,
        j_date,
    )
}

/// Ch. 3 W4: synthetic distribution-shift stream joined with the small
/// uniform dimension table.
pub fn synthetic_join(rows: usize, workers: usize, seed: u64) -> Flow {
    synthetic_join_costed(rows, workers, seed, 0)
}

/// [`synthetic_join`] with an artificial per-probe-tuple join cost.
pub fn synthetic_join_costed(
    rows: usize,
    workers: usize,
    seed: u64,
    probe_cost_ns: u64,
) -> Flow {
    let mut w = Workflow::new();
    let dim = w.add(OpSpec::source("scan_dim", 1, |idx, parts| {
        let rows: Vec<Tuple> = synthetic::dim_table(100)
            .into_iter()
            .enumerate()
            .filter(|(i, _)| i % parts == idx)
            .map(|(_, t)| t)
            .collect();
        Box::new(VecSource::new(rows)) as Box<dyn TupleSource>
    }));
    let stream = w.add(OpSpec::source("scan_stream", 2, move |idx, parts| {
        Box::new(ShiftingSource::new(rows, parts, idx, seed)) as Box<dyn TupleSource>
    }));
    let join = w.add(OpSpec::binary(
        "join",
        workers,
        [
            PartitionScheme::Hash { key: synthetic::F_KEY },
            PartitionScheme::Hash { key: synthetic::F_KEY },
        ],
        vec![0],
        move |_, _| {
            Box::new(HashJoin::new(synthetic::F_KEY, synthetic::F_KEY).with_probe_cost(probe_cost_ns))
        },
    ));
    let sink_handle = SinkHandle::new(synthetic::NUM_KEYS as usize);
    let h = sink_handle.clone();
    let sink = w.add(OpSpec::unary("sink", 1, PartitionScheme::RoundRobin, move |_, _| {
        Box::new(CountByKeySink::new(h.clone(), 2 + synthetic::F_KEY))
    }));
    w.connect(dim, join, 0);
    w.connect(stream, join, 1);
    w.connect(join, sink, 0);
    Flow { workflow: w, sink: sink_handle, focus: join, sink_op: sink }
}

/// The join worker owning a given integer key under hash partitioning.
pub fn worker_of_key(key: i64, workers: usize) -> usize {
    (Value::Int(key).stable_hash() % workers as u64) as usize
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::engine::Execution;

    #[test]
    fn q1_runs_and_produces_groups() {
        let f = tpch_q1(0.05, 2);
        let exec = Execution::start(f.workflow, Config::for_tests());
        exec.join();
        // returnflag has 3 distinct values.
        assert_eq!(f.sink.total(), 3);
    }

    #[test]
    fn q13_runs() {
        let f = tpch_q13(0.05, 2);
        let exec = Execution::start(f.workflow, Config::for_tests());
        exec.join();
        assert!(f.sink.total() > 0);
    }

    #[test]
    fn sort_flow_totally_ordered() {
        let f = orders_sort(0.05, 3);
        let exec = Execution::start(f.workflow, Config::for_tests());
        exec.join();
        let rows = f.sink.tuples();
        assert!(rows.len() > 400, "got {}", rows.len());
        let prices: Vec<f64> = rows
            .iter()
            .map(|t| t.get(tpch::O_TOTALPRICE).as_float().unwrap())
            .collect();
        assert!(prices.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn dsb_flow_counts_categories() {
        let (f, _, _) = dsb_q18(5_000, 4, 3);
        let exec = Execution::start(f.workflow, Config::for_tests());
        exec.join();
        assert_eq!(f.sink.total(), dsb::NUM_CATEGORIES as u64);
    }

    #[test]
    fn synthetic_flow_joins_every_row() {
        let f = synthetic_join(10_000, 4, 9);
        let exec = Execution::start(f.workflow, Config::for_tests());
        let s = exec.join();
        // Every stream row matches 100 dim rows.
        assert_eq!(s.produced(f.focus), 10_000 * 100);
    }
}

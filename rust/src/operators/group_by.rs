//! Two-layer group-by (§2.4.3 category 4): first-layer workers compute
//! local partial aggregates; a hash-partitioned second layer finalizes
//! per-group results. Both layers are mutable-state operators
//! (Table 3.1), so SBK migration must be marker-synchronized and SBR
//! produces scattered states merged at EOF (§3.5.4's blocking-operator
//! conditions hold: group-by can combine scattered parts and blocks
//! until EOF).
//!
//! **Out-of-core** (see `docs/ARCHITECTURE.md` "Out-of-core
//! execution"): past the execution's memory budget either layer evicts
//! its owned resident groups to per-partition spill files as `(key,
//! partial...)` rows — aggregates combine associatively, so a group
//! may be flushed many times and re-combined at read-back. At EOF a
//! spilled layer emits partition by partition (recursively
//! re-partitioned by the next hash nibble while a partition still
//! exceeds the budget); foreign groups held under SBR mitigation never
//! spill, because [`Operator::scattered_parts`] must ship them to
//! their hash owners from resident memory.

use crate::engine::operator::{Emitter, OpState, Operator};
use crate::engine::spill::{
    partition_of, read_slot_rows, rows_byte_size, MemLease, SpillCtx, SpillFile, SpillReader,
    SpillSlot, SPILL_FANOUT, SPILL_MAX_DEPTH,
};
use crate::tuple::{Tuple, TupleBatch, Value};
use std::collections::{BTreeMap, HashMap};

/// Aggregate kinds.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AggKind {
    Count,
    Sum,
    Min,
    Max,
    /// Sum + count → final layer emits the mean.
    Avg,
}

/// Per-group accumulator: [primary, secondary(count for avg)].
fn init_acc(kind: AggKind) -> Vec<f64> {
    match kind {
        AggKind::Count => vec![0.0],
        AggKind::Sum => vec![0.0],
        AggKind::Min => vec![f64::INFINITY],
        AggKind::Max => vec![f64::NEG_INFINITY],
        AggKind::Avg => vec![0.0, 0.0],
    }
}

fn acc_width(kind: AggKind) -> usize {
    match kind {
        AggKind::Avg => 2,
        _ => 1,
    }
}

fn accumulate(kind: AggKind, acc: &mut [f64], v: f64) {
    match kind {
        AggKind::Count => acc[0] += 1.0,
        AggKind::Sum => acc[0] += v,
        AggKind::Min => acc[0] = acc[0].min(v),
        AggKind::Max => acc[0] = acc[0].max(v),
        AggKind::Avg => {
            acc[0] += v;
            acc[1] += 1.0;
        }
    }
}

fn combine(kind: AggKind, acc: &mut [f64], other: &[f64]) {
    match kind {
        AggKind::Count | AggKind::Sum => acc[0] += other[0],
        AggKind::Min => acc[0] = acc[0].min(other[0]),
        AggKind::Max => acc[0] = acc[0].max(other[0]),
        AggKind::Avg => {
            acc[0] += other[0];
            acc[1] += other[1];
        }
    }
}

fn finalize(kind: AggKind, acc: &[f64]) -> f64 {
    match kind {
        AggKind::Avg => {
            if acc[1] > 0.0 {
                acc[0] / acc[1]
            } else {
                0.0
            }
        }
        _ => acc[0],
    }
}

// ---- shared out-of-core machinery ----

/// Spill-slot tag: a group-by layer has one stream kind — partial rows.
const TAG_GROUPS: u32 = 0;

/// Approximate resident footprint of one group entry: the key value,
/// the f64 accumulator slots, and map/entry overhead.
fn group_bytes(key: &Value, width: usize) -> u64 {
    key.byte_size() as u64 + 8 * width as u64 + 24
}

/// A group as a self-describing spill row: `(key, partial...)` — the
/// group hash is recomputed from the key at read-back.
fn group_row(key: &Value, acc: &[f64]) -> Tuple {
    let mut vals = Vec::with_capacity(1 + acc.len());
    vals.push(key.clone());
    vals.extend(acc.iter().map(|a| Value::Float(*a)));
    Tuple::new(vals)
}

/// Combine one spilled `(key, partial...)` row back into a group map.
fn absorb_partial_row(groups: &mut HashMap<u64, (Value, Vec<f64>)>, kind: AggKind, t: &Tuple) {
    let h = t.get(0).stable_hash();
    let partial: Vec<f64> = (1..t.arity())
        .map(|i| t.get(i).as_float().unwrap_or(0.0))
        .collect();
    match groups.entry(h) {
        std::collections::hash_map::Entry::Occupied(mut e) => {
            combine(kind, &mut e.get_mut().1, &partial);
        }
        std::collections::hash_map::Entry::Vacant(e) => {
            e.insert((t.get(0).clone(), partial));
        }
    }
}

fn emit_row(key: &Value, acc: &[f64], kind: AggKind, emit_final: bool) -> Tuple {
    if emit_final {
        Tuple::new(vec![key.clone(), Value::Float(finalize(kind, acc))])
    } else {
        group_row(key, acc)
    }
}

/// Per-layer out-of-core state, shared by both group-by layers.
/// Without an attached [`SpillCtx`] every method is a no-op and the
/// resident path is byte-identical to the pre-spill implementation.
#[derive(Default)]
struct GroupSpill {
    ctx: Option<SpillCtx>,
    lease: MemLease,
    resident_bytes: u64,
    files: BTreeMap<u64, SpillFile>,
}

impl GroupSpill {
    fn attach(&mut self, ctx: &SpillCtx) {
        self.lease = MemLease::new(ctx.budget.clone());
        self.ctx = Some(ctx.clone());
    }

    /// Whether per-group byte accounting is worth doing at all.
    fn tracking(&self) -> bool {
        self.ctx.is_some()
    }

    fn note_new_group(&mut self, key: &Value, width: usize) {
        self.resident_bytes += group_bytes(key, width);
    }

    fn has_files(&self) -> bool {
        !self.files.is_empty()
    }

    /// Re-sync the budget charge after a bulk mutation of the map.
    fn reset_resident(&mut self, groups: &HashMap<u64, (Value, Vec<f64>)>) {
        if !self.tracking() {
            return;
        }
        self.resident_bytes = groups
            .values()
            .map(|(k, a)| group_bytes(k, a.len()))
            .sum();
        self.lease.set(self.resident_bytes);
    }

    /// Evict owned resident groups to per-partition files when over
    /// budget. Foreign groups (scattered state held for other hash
    /// owners under SBR) stay resident — `scattered_parts` ships them
    /// at EOF from memory.
    fn maybe_spill(
        &mut self,
        groups: &mut HashMap<u64, (Value, Vec<f64>)>,
        ownership: Option<(usize, usize)>,
    ) {
        let Some(ctx) = self.ctx.clone() else { return };
        self.lease.set(self.resident_bytes);
        if !ctx.budget.over() || groups.is_empty() {
            return;
        }
        self.flush(&ctx, groups, ownership);
    }

    fn flush(
        &mut self,
        ctx: &SpillCtx,
        groups: &mut HashMap<u64, (Value, Vec<f64>)>,
        ownership: Option<(usize, usize)>,
    ) {
        let mut by_part: BTreeMap<u64, Vec<(u64, Value, Vec<f64>)>> = BTreeMap::new();
        let mut keep = HashMap::new();
        let mut kept_bytes = 0u64;
        for (h, (key, acc)) in groups.drain() {
            let foreign =
                matches!(ownership, Some((idx, n)) if (h % n as u64) as usize != idx);
            if foreign {
                kept_bytes += group_bytes(&key, acc.len());
                keep.insert(h, (key, acc));
            } else {
                by_part
                    .entry(partition_of(h, 0) as u64)
                    .or_default()
                    .push((h, key, acc));
            }
        }
        *groups = keep;
        for (p, mut rows) in by_part {
            rows.sort_by_key(|(h, _, _)| *h); // deterministic file content
            let tuples: Vec<Tuple> = rows.iter().map(|(_, k, a)| group_row(k, a)).collect();
            let file = self.files.entry(p).or_insert_with(|| {
                ctx.counters.add_partition();
                SpillFile::create(ctx, TAG_GROUPS, p, 0)
            });
            file.append(&tuples);
        }
        self.resident_bytes = kept_bytes;
        self.lease.set(self.resident_bytes);
    }

    /// Read every spilled partition back into the resident map —
    /// state-extraction paths (migration/scale) work on resident state.
    /// The files stay on disk, orphaned, until the execution's spill
    /// directory is reclaimed at teardown.
    fn unspill(&mut self, groups: &mut HashMap<u64, (Value, Vec<f64>)>, kind: AggKind) {
        let Some(ctx) = self.ctx.clone() else { return };
        let files = std::mem::take(&mut self.files);
        for (_, f) in files {
            for t in read_slot_rows(&ctx, &f.slot()) {
                absorb_partial_row(groups, kind, &t);
            }
        }
        self.reset_resident(groups);
    }

    fn snapshot_slots(&self) -> Vec<SpillSlot> {
        self.files.values().map(|f| f.slot()).collect()
    }

    fn restore_slots(&mut self, slots: Vec<SpillSlot>) {
        self.files.clear();
        if slots.is_empty() {
            return;
        }
        let ctx = self.ctx.clone().expect("spill ctx attached before restore");
        for slot in slots {
            self.files.insert(slot.scope, SpillFile::reopen(&ctx, &slot));
        }
    }

    /// EOF emission once anything spilled: flush the owned remainder,
    /// then combine and emit partition by partition. Output order is
    /// (partition, hash) rather than global hash order — group-by
    /// output is consumed as a multiset (an exchange or a sink
    /// comparison), so only the set of rows must match the resident
    /// path, and it does: combining is associative.
    fn finish_emit(
        &mut self,
        groups: &mut HashMap<u64, (Value, Vec<f64>)>,
        ownership: Option<(usize, usize)>,
        kind: AggKind,
        emit_final: bool,
        out: &mut dyn Emitter,
    ) {
        let ctx = self.ctx.clone().expect("spill ctx attached");
        self.flush(&ctx, groups, ownership);
        let files = std::mem::take(&mut self.files);
        for (_, f) in files {
            self.emit_partition(&ctx, f.slot(), 0, kind, emit_final, out);
        }
        // Foreign remainder (held for other owners but never shipped —
        // no scatter-merge pairing): emit hash-sorted like the
        // resident path.
        let mut keys: Vec<u64> = groups.keys().copied().collect();
        keys.sort_unstable();
        for h in keys {
            let (key, acc) = &groups[&h];
            out.emit(emit_row(key, acc, kind, emit_final));
        }
        groups.clear();
        self.reset_resident(groups);
    }

    /// Combine-and-emit one spilled partition, recursively
    /// re-partitioning by the next hash nibble while its file still
    /// exceeds the budget (bounded by [`SPILL_MAX_DEPTH`], past which
    /// it is combined in memory regardless — correctness over
    /// strictness).
    fn emit_partition(
        &mut self,
        ctx: &SpillCtx,
        slot: SpillSlot,
        depth: u32,
        kind: AggKind,
        emit_final: bool,
        out: &mut dyn Emitter,
    ) {
        ctx.counters.observe_depth(depth);
        let limit = ctx.budget.limit();
        if limit > 0 && slot.bytes > limit && depth < SPILL_MAX_DEPTH {
            let next = depth + 1;
            let mut subs: Vec<Option<SpillFile>> = (0..SPILL_FANOUT).map(|_| None).collect();
            let mut reader = SpillReader::open(ctx, &slot);
            while let Some(rows) = reader.next_rows() {
                let mut buckets: Vec<Vec<Tuple>> =
                    (0..SPILL_FANOUT).map(|_| Vec::new()).collect();
                for t in rows {
                    buckets[partition_of(t.get(0).stable_hash(), next)].push(t);
                }
                for (i, b) in buckets.into_iter().enumerate() {
                    if b.is_empty() {
                        continue;
                    }
                    let scope = (slot.scope << 4) | i as u64;
                    let f = subs[i].get_or_insert_with(|| {
                        ctx.counters.add_partition();
                        SpillFile::create(ctx, TAG_GROUPS, scope, 0)
                    });
                    f.append(&b);
                }
            }
            for s in subs.iter_mut() {
                if let Some(f) = s.take() {
                    self.emit_partition(ctx, f.slot(), next, kind, emit_final, out);
                }
            }
            return;
        }
        // Terminal: combine the partition in memory (charged against
        // the budget for the duration) and emit hash-sorted.
        let rows = read_slot_rows(ctx, &slot);
        let mut lease = MemLease::new(ctx.budget.clone());
        lease.set(rows_byte_size(&rows));
        let mut map: HashMap<u64, (Value, Vec<f64>)> = HashMap::new();
        for t in &rows {
            absorb_partial_row(&mut map, kind, t);
        }
        let mut keys: Vec<u64> = map.keys().copied().collect();
        keys.sort_unstable();
        for h in keys {
            let (key, acc) = &map[&h];
            out.emit(emit_row(key, acc, kind, emit_final));
        }
    }
}

/// First layer: local partial aggregation; emits (group_key,
/// partial...) at EOF. Keeps the *group value* alongside the hash so
/// output tuples carry the real key.
pub struct GroupByPartial {
    pub key_field: usize,
    /// Value field (ignored for COUNT).
    pub value_field: usize,
    pub kind: AggKind,
    /// Artificial per-tuple cost in ns, modelled as a *sleep* like
    /// [`MapUdf`](crate::operators::basic::MapUdf): latency-bound work
    /// (the paper's expensive UDF operators) that more workers absorb
    /// even on a single core — the elastic-scaling benchmark workload.
    pub cost_ns: u64,
    groups: HashMap<u64, (Value, Vec<f64>)>,
    spill: GroupSpill,
}

impl GroupByPartial {
    pub fn new(key_field: usize, value_field: usize, kind: AggKind) -> GroupByPartial {
        GroupByPartial {
            key_field,
            value_field,
            kind,
            cost_ns: 0,
            groups: HashMap::new(),
            spill: GroupSpill::default(),
        }
    }

    /// Builder: artificial latency-bound per-tuple cost.
    pub fn with_cost(mut self, ns: u64) -> GroupByPartial {
        self.cost_ns = ns;
        self
    }

    #[inline]
    fn absorb(&mut self, t: &Tuple) {
        let h = t.get(self.key_field).stable_hash();
        self.absorb_hashed(t, h);
    }

    /// Row absorb with a pre-computed group hash (shipped by the
    /// exchange); skips `stable_hash` but is otherwise identical.
    #[inline]
    fn absorb_hashed(&mut self, t: &Tuple, h: u64) {
        let v = t.get(self.value_field).as_float().unwrap_or(0.0);
        let kind = self.kind;
        let kf = self.key_field;
        if self.spill.tracking() && !self.groups.contains_key(&h) {
            self.spill.note_new_group(t.get(kf), acc_width(kind));
        }
        let entry = self
            .groups
            .entry(h)
            .or_insert_with(|| (t.get(kf).clone(), init_acc(kind)));
        accumulate(kind, &mut entry.1, v);
    }

    /// Column-at-a-time absorb: hash the key column (or reuse shipped
    /// hashes), coerce the value column to `f64` in one pass, then run
    /// the accumulator loop over flat slices. Returns `false` when the
    /// batch has no columnar layout (caller falls back to rows).
    fn absorb_columnar(&mut self, batch: &TupleBatch, hashes: Option<&[u64]>) -> bool {
        let Some(cv) = batch.columns() else { return false };
        let (Some(key_col), Some(val_col)) =
            (cv.set.cols.get(self.key_field), cv.set.cols.get(self.value_field))
        else {
            return false;
        };
        let mut hbuf = Vec::new();
        let hs: &[u64] = match hashes {
            Some(hs) => hs,
            None => {
                key_col.hash_range(cv.start, cv.end, &mut hbuf);
                &hbuf
            }
        };
        let mut vbuf = Vec::new();
        val_col.float_or_zero_range(cv.start, cv.end, &mut vbuf);
        let kind = self.kind;
        let track = self.spill.tracking();
        for (i, (&h, &v)) in hs.iter().zip(vbuf.iter()).enumerate() {
            if track && !self.groups.contains_key(&h) {
                self.spill
                    .note_new_group(&key_col.value_at(cv.start + i), acc_width(kind));
            }
            let entry = self
                .groups
                .entry(h)
                .or_insert_with(|| (key_col.value_at(cv.start + i), init_acc(kind)));
            accumulate(kind, &mut entry.1, v);
        }
        true
    }
}

impl Operator for GroupByPartial {
    fn name(&self) -> &str {
        "group_by_partial"
    }

    fn attach_spill(&mut self, ctx: &SpillCtx) {
        self.spill.attach(ctx);
    }

    fn process(&mut self, t: Tuple, _port: usize, _out: &mut dyn Emitter) {
        if self.cost_ns > 0 {
            std::thread::sleep(std::time::Duration::from_nanos(self.cost_ns));
        }
        self.absorb(&t);
        self.spill.maybe_spill(&mut self.groups, None);
    }

    /// Pre-aggregation reads tuples straight out of the shared batch —
    /// no per-tuple clone, one dispatch per chunk. Columnar batches
    /// take the vectorized absorb (typed key hashing + one-pass float
    /// coercion); row batches keep the per-tuple loop. The artificial
    /// cost sleeps once per chunk (chunk length × per-tuple cost),
    /// keeping pause latency bounded by one chunk.
    fn process_batch(&mut self, batch: &TupleBatch, _port: usize, _out: &mut dyn Emitter) {
        if self.cost_ns > 0 && !batch.is_empty() {
            std::thread::sleep(std::time::Duration::from_nanos(
                self.cost_ns * batch.len() as u64,
            ));
        }
        if !self.absorb_columnar(batch, None) {
            for t in batch.iter() {
                self.absorb(t);
            }
        }
        self.spill.maybe_spill(&mut self.groups, None);
    }

    /// Shipped-hash fast path: when the exchange partitioned on this
    /// operator's group key, the shipped column *is* the group hash —
    /// skip re-hashing entirely.
    fn process_batch_hashed(
        &mut self,
        batch: &TupleBatch,
        key: usize,
        hashes: &[u64],
        port: usize,
        out: &mut dyn Emitter,
    ) {
        if key != self.key_field {
            self.process_batch(batch, port, out);
            return;
        }
        if self.cost_ns > 0 && !batch.is_empty() {
            std::thread::sleep(std::time::Duration::from_nanos(
                self.cost_ns * batch.len() as u64,
            ));
        }
        if !self.absorb_columnar(batch, Some(hashes)) {
            for (t, &h) in batch.iter().zip(hashes.iter()) {
                self.absorb_hashed(t, h);
            }
        }
        self.spill.maybe_spill(&mut self.groups, None);
    }

    fn finish(&mut self, out: &mut dyn Emitter) {
        if self.spill.has_files() {
            self.spill
                .finish_emit(&mut self.groups, None, self.kind, false, out);
            return;
        }
        // Emit (key, partial0[, partial1]) for the final layer.
        let mut keys: Vec<u64> = self.groups.keys().copied().collect();
        keys.sort_unstable(); // deterministic output order (A3)
        for h in keys {
            let (key, acc) = &self.groups[&h];
            let mut vals = vec![key.clone()];
            vals.extend(acc.iter().map(|a| Value::Float(*a)));
            out.emit(Tuple::new(vals));
        }
    }

    fn snapshot(&self) -> OpState {
        let mut s = OpState::default();
        for (h, (key, acc)) in &self.groups {
            s.keyed_aggs.insert(*h, acc.clone());
            s.keyed_tuples
                .insert(*h, vec![Tuple::new(vec![key.clone()])]);
        }
        s.spill = self.spill.snapshot_slots();
        s
    }

    fn restore(&mut self, mut s: OpState) {
        self.spill.restore_slots(std::mem::take(&mut s.spill));
        self.groups.clear();
        for (h, acc) in s.keyed_aggs {
            let key = s.keyed_tuples
                .get(&h)
                .and_then(|v| v.first())
                .map(|t| t.get(0).clone())
                .unwrap_or(Value::Null);
            self.groups.insert(h, (key, acc));
        }
        self.spill.reset_resident(&self.groups);
    }

    fn state_size(&self) -> usize {
        self.groups.len()
    }

    fn extract_state(&mut self, keys: Option<&[u64]>, replicate: bool) -> OpState {
        self.spill.unspill(&mut self.groups, self.kind);
        let mut out = OpState::default();
        let targets: Vec<u64> = match keys {
            None => self.groups.keys().copied().collect(),
            Some(ks) => ks.to_vec(),
        };
        for h in targets {
            let item = if replicate {
                self.groups.get(&h).cloned()
            } else {
                self.groups.remove(&h)
            };
            if let Some((key, acc)) = item {
                out.keyed_aggs.insert(h, acc);
                out.keyed_tuples.insert(h, vec![Tuple::new(vec![key])]);
            }
        }
        self.spill.reset_resident(&self.groups);
        out
    }

    fn merge_state(&mut self, s: OpState) {
        for (h, acc) in s.keyed_aggs {
            let key = s.keyed_tuples
                .get(&h)
                .and_then(|v| v.first())
                .map(|t| t.get(0).clone())
                .unwrap_or(Value::Null);
            match self.groups.entry(h) {
                std::collections::hash_map::Entry::Occupied(mut e) => {
                    combine(self.kind, &mut e.get_mut().1, &acc);
                }
                std::collections::hash_map::Entry::Vacant(e) => {
                    e.insert((key, acc));
                }
            }
        }
        self.spill.reset_resident(&self.groups);
        self.spill.maybe_spill(&mut self.groups, None);
    }

    fn state_mutable(&self) -> bool {
        true
    }
}

/// Second layer: combines partials (input: (key, partial...) hashed by
/// key) and emits final (key, aggregate) at EOF.
pub struct GroupByFinal {
    pub kind: AggKind,
    groups: HashMap<u64, (Value, Vec<f64>)>,
    /// (worker idx, worker count) under hash partitioning — set when
    /// the operator runs under SBR mitigation so foreign groups
    /// (scattered state, §3.5.4) can be shipped to their owners at EOF.
    ownership: Option<(usize, usize)>,
    spill: GroupSpill,
}

impl GroupByFinal {
    pub fn new(kind: AggKind) -> GroupByFinal {
        GroupByFinal {
            kind,
            groups: HashMap::new(),
            ownership: None,
            spill: GroupSpill::default(),
        }
    }

    /// Group-by worker `idx` of `n` under hash partitioning; enables
    /// scattered-state resolution (pair with
    /// [`OpSpec::with_scatter_merge`](crate::engine::dag::OpSpec::with_scatter_merge)).
    pub fn new_partitioned(kind: AggKind, idx: usize, n: usize) -> GroupByFinal {
        GroupByFinal {
            kind,
            groups: HashMap::new(),
            ownership: Some((idx, n)),
            spill: GroupSpill::default(),
        }
    }

    #[inline]
    fn absorb(&mut self, t: &Tuple) {
        let h = t.get(0).stable_hash();
        self.absorb_hashed(t, h);
    }

    /// Combine one `(key, partial...)` row under a pre-computed group
    /// hash (shipped by the hash exchange or derived locally).
    #[inline]
    fn absorb_hashed(&mut self, t: &Tuple, h: u64) {
        let partial: Vec<f64> = (1..t.arity())
            .map(|i| t.get(i).as_float().unwrap_or(0.0))
            .collect();
        if self.spill.tracking() && !self.groups.contains_key(&h) {
            self.spill.note_new_group(t.get(0), partial.len());
        }
        match self.groups.entry(h) {
            std::collections::hash_map::Entry::Occupied(mut e) => {
                combine(self.kind, &mut e.get_mut().1, &partial);
            }
            std::collections::hash_map::Entry::Vacant(e) => {
                e.insert((t.get(0).clone(), partial));
            }
        }
    }

    /// Column-at-a-time combine: hash the key column once (or reuse
    /// shipped hashes) and coerce every partial column to `f64` in one
    /// pass each, then merge row-wise over flat slices.
    fn absorb_columnar(&mut self, batch: &TupleBatch, hashes: Option<&[u64]>) -> bool {
        let Some(cv) = batch.columns() else { return false };
        let Some(key_col) = cv.set.cols.first() else { return false };
        let arity = cv.set.arity();
        if arity < 2 {
            return false;
        }
        let mut hbuf = Vec::new();
        let hs: &[u64] = match hashes {
            Some(hs) => hs,
            None => {
                key_col.hash_range(cv.start, cv.end, &mut hbuf);
                &hbuf
            }
        };
        let mut part_cols: Vec<Vec<f64>> = Vec::with_capacity(arity - 1);
        for c in &cv.set.cols[1..] {
            let mut v = Vec::new();
            c.float_or_zero_range(cv.start, cv.end, &mut v);
            part_cols.push(v);
        }
        let kind = self.kind;
        let track = self.spill.tracking();
        for (i, &h) in hs.iter().enumerate() {
            let partial: Vec<f64> = part_cols.iter().map(|c| c[i]).collect();
            if track && !self.groups.contains_key(&h) {
                self.spill
                    .note_new_group(&key_col.value_at(cv.start + i), partial.len());
            }
            match self.groups.entry(h) {
                std::collections::hash_map::Entry::Occupied(mut e) => {
                    combine(kind, &mut e.get_mut().1, &partial);
                }
                std::collections::hash_map::Entry::Vacant(e) => {
                    e.insert((key_col.value_at(cv.start + i), partial));
                }
            }
        }
        true
    }
}

impl Operator for GroupByFinal {
    fn name(&self) -> &str {
        "group_by_final"
    }

    fn blocking_ports(&self) -> Vec<usize> {
        vec![0]
    }

    fn attach_spill(&mut self, ctx: &SpillCtx) {
        self.spill.attach(ctx);
    }

    fn process(&mut self, t: Tuple, _port: usize, _out: &mut dyn Emitter) {
        self.absorb(&t);
        let own = self.ownership;
        self.spill.maybe_spill(&mut self.groups, own);
    }

    fn process_batch(&mut self, batch: &TupleBatch, _port: usize, _out: &mut dyn Emitter) {
        if !self.absorb_columnar(batch, None) {
            for t in batch.iter() {
                self.absorb(t);
            }
        }
        let own = self.ownership;
        self.spill.maybe_spill(&mut self.groups, own);
    }

    /// Shipped-hash fast path: the final layer is hash-partitioned on
    /// field 0 (the group key), so the exchange's shipped column is
    /// byte-equal to the group hash — reuse it verbatim.
    fn process_batch_hashed(
        &mut self,
        batch: &TupleBatch,
        key: usize,
        hashes: &[u64],
        port: usize,
        out: &mut dyn Emitter,
    ) {
        if key != 0 {
            self.process_batch(batch, port, out);
            return;
        }
        if !self.absorb_columnar(batch, Some(hashes)) {
            for (t, &h) in batch.iter().zip(hashes.iter()) {
                self.absorb_hashed(t, h);
            }
        }
        let own = self.ownership;
        self.spill.maybe_spill(&mut self.groups, own);
    }

    fn finish(&mut self, out: &mut dyn Emitter) {
        if self.spill.has_files() {
            let own = self.ownership;
            self.spill
                .finish_emit(&mut self.groups, own, self.kind, true, out);
            return;
        }
        let mut keys: Vec<u64> = self.groups.keys().copied().collect();
        keys.sort_unstable();
        for h in keys {
            let (key, acc) = &self.groups[&h];
            out.emit(Tuple::new(vec![
                key.clone(),
                Value::Float(finalize(self.kind, acc)),
            ]));
        }
    }

    fn snapshot(&self) -> OpState {
        let mut s = OpState::default();
        for (h, (key, acc)) in &self.groups {
            s.keyed_aggs.insert(*h, acc.clone());
            s.keyed_tuples
                .insert(*h, vec![Tuple::new(vec![key.clone()])]);
        }
        s.spill = self.spill.snapshot_slots();
        s
    }

    fn restore(&mut self, mut s: OpState) {
        self.spill.restore_slots(std::mem::take(&mut s.spill));
        self.groups.clear();
        for (h, acc) in s.keyed_aggs {
            let key = s.keyed_tuples
                .get(&h)
                .and_then(|v| v.first())
                .map(|t| t.get(0).clone())
                .unwrap_or(Value::Null);
            self.groups.insert(h, (key, acc));
        }
        self.spill.reset_resident(&self.groups);
    }

    fn state_size(&self) -> usize {
        self.groups.len()
    }

    fn extract_state(&mut self, keys: Option<&[u64]>, replicate: bool) -> OpState {
        self.spill.unspill(&mut self.groups, self.kind);
        let mut out = OpState::default();
        let targets: Vec<u64> = match keys {
            None => self.groups.keys().copied().collect(),
            Some(ks) => ks.to_vec(),
        };
        for h in targets {
            let item = if replicate {
                self.groups.get(&h).cloned()
            } else {
                self.groups.remove(&h)
            };
            if let Some((key, acc)) = item {
                out.keyed_aggs.insert(h, acc);
                out.keyed_tuples.insert(h, vec![Tuple::new(vec![key])]);
            }
        }
        self.spill.reset_resident(&self.groups);
        out
    }

    fn merge_state(&mut self, s: OpState) {
        // Scattered-state merge (§3.5.4): partial aggregates for the
        // same group combine associatively.
        for (h, acc) in s.keyed_aggs {
            let key = s.keyed_tuples
                .get(&h)
                .and_then(|v| v.first())
                .map(|t| t.get(0).clone())
                .unwrap_or(Value::Null);
            match self.groups.entry(h) {
                std::collections::hash_map::Entry::Occupied(mut e) => {
                    combine(self.kind, &mut e.get_mut().1, &acc);
                }
                std::collections::hash_map::Entry::Vacant(e) => {
                    e.insert((key, acc));
                }
            }
        }
        self.spill.reset_resident(&self.groups);
        let own = self.ownership;
        self.spill.maybe_spill(&mut self.groups, own);
    }

    fn state_mutable(&self) -> bool {
        true
    }

    fn rescale(&mut self, idx: usize, workers: usize) {
        // Elastic scaling moved this instance into a `workers`-wide
        // hash-partitioned set; scattered-state ownership follows.
        if self.ownership.is_some() {
            self.ownership = Some((idx, workers));
        }
    }

    fn scattered_parts(&mut self) -> Vec<(u64, OpState)> {
        // Ship foreign groups (received through mitigation routes) back
        // to their hash owners at EOF (§3.5.4): aggregates combine
        // associatively, so the owner's merge_state yields exact totals.
        // Foreign groups never spill (GroupSpill keeps them resident),
        // so this works off the in-memory map alone.
        let Some((idx, n)) = self.ownership else { return Vec::new() };
        let foreign: Vec<u64> = self
            .groups
            .keys()
            .copied()
            .filter(|h| (*h % n as u64) as usize != idx)
            .collect();
        let mut by_owner: HashMap<u64, OpState> = HashMap::new();
        for h in foreign {
            let owner = h % n as u64;
            let (key, acc) = self.groups.remove(&h).unwrap();
            let st = by_owner.entry(owner).or_default();
            st.keyed_aggs.insert(h, acc);
            st.keyed_tuples.insert(h, vec![Tuple::new(vec![key])]);
        }
        self.spill.reset_resident(&self.groups);
        by_owner.into_iter().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::engine::operator::VecEmitter;

    fn t2(k: i64, v: f64) -> Tuple {
        Tuple::new(vec![Value::Int(k), Value::Float(v)])
    }

    fn run_two_layer(kind: AggKind, input: Vec<Tuple>) -> HashMap<i64, f64> {
        run_two_layer_ctx(kind, input, None)
    }

    fn run_two_layer_ctx(
        kind: AggKind,
        input: Vec<Tuple>,
        ctx: Option<&SpillCtx>,
    ) -> HashMap<i64, f64> {
        let mut partial = GroupByPartial::new(0, 1, kind);
        let mut fin = GroupByFinal::new(kind);
        if let Some(c) = ctx {
            partial.attach_spill(c);
            fin.attach_spill(c);
        }
        let mut out1 = VecEmitter::default();
        for t in input {
            partial.process(t, 0, &mut out1);
        }
        partial.finish(&mut out1);
        let mut out2 = VecEmitter::default();
        for t in out1.0 {
            fin.process(t, 0, &mut out2);
        }
        fin.finish(&mut out2);
        out2.0
            .iter()
            .map(|t| (t.get(0).as_int().unwrap(), t.get(1).as_float().unwrap()))
            .collect()
    }

    #[test]
    fn count_per_group() {
        let r = run_two_layer(
            AggKind::Count,
            vec![t2(1, 0.0), t2(1, 0.0), t2(2, 0.0)],
        );
        assert_eq!(r[&1], 2.0);
        assert_eq!(r[&2], 1.0);
    }

    #[test]
    fn sum_and_avg() {
        let r = run_two_layer(AggKind::Sum, vec![t2(1, 2.0), t2(1, 3.0)]);
        assert_eq!(r[&1], 5.0);
        let r = run_two_layer(AggKind::Avg, vec![t2(1, 2.0), t2(1, 4.0)]);
        assert_eq!(r[&1], 3.0);
    }

    #[test]
    fn min_max() {
        let r = run_two_layer(AggKind::Min, vec![t2(1, 5.0), t2(1, 2.0)]);
        assert_eq!(r[&1], 2.0);
        let r = run_two_layer(AggKind::Max, vec![t2(1, 5.0), t2(1, 2.0)]);
        assert_eq!(r[&1], 5.0);
    }

    #[test]
    fn partials_combine_across_workers() {
        // Two partial workers, one final worker.
        let mut p1 = GroupByPartial::new(0, 1, AggKind::Sum);
        let mut p2 = GroupByPartial::new(0, 1, AggKind::Sum);
        let (mut o1, mut o2) = (VecEmitter::default(), VecEmitter::default());
        p1.process(t2(1, 1.0), 0, &mut o1);
        p2.process(t2(1, 2.0), 0, &mut o2);
        p1.finish(&mut o1);
        p2.finish(&mut o2);
        let mut f = GroupByFinal::new(AggKind::Sum);
        let mut of = VecEmitter::default();
        for t in o1.0.into_iter().chain(o2.0) {
            f.process(t, 0, &mut of);
        }
        f.finish(&mut of);
        assert_eq!(of.0.len(), 1);
        assert_eq!(of.0[0].get(1).as_float(), Some(3.0));
    }

    #[test]
    fn scattered_state_merges() {
        // SBR split the same group across two final workers; merging
        // their states must equal single-worker processing (§3.5.4).
        let mut a = GroupByFinal::new(AggKind::Count);
        let mut b = GroupByFinal::new(AggKind::Count);
        let mut o = VecEmitter::default();
        a.process(Tuple::new(vec![Value::Int(1), Value::Float(2.0)]), 0, &mut o);
        b.process(Tuple::new(vec![Value::Int(1), Value::Float(3.0)]), 0, &mut o);
        let scattered = b.extract_state(None, false);
        a.merge_state(scattered);
        let mut out = VecEmitter::default();
        a.finish(&mut out);
        assert_eq!(out.0.len(), 1);
        assert_eq!(out.0[0].get(1).as_float(), Some(5.0));
        assert_eq!(b.state_size(), 0);
    }

    #[test]
    fn batched_aggregation_matches_per_tuple() {
        let rows: Vec<Tuple> = (0..50).map(|i| t2(i % 5, i as f64)).collect();
        let mut per = GroupByPartial::new(0, 1, AggKind::Sum);
        let mut out = VecEmitter::default();
        for r in &rows {
            per.process(r.clone(), 0, &mut out);
        }
        let mut batched = GroupByPartial::new(0, 1, AggKind::Sum);
        batched.process_batch(&rows.into(), 0, &mut out);
        let mut oa = VecEmitter::default();
        let mut ob = VecEmitter::default();
        per.finish(&mut oa);
        batched.finish(&mut ob);
        assert_eq!(oa.0, ob.0);
    }

    #[test]
    fn columnar_and_shipped_hash_paths_match_per_tuple() {
        let rows: Vec<Tuple> = (0..60).map(|i| t2(i % 7, i as f64 * 0.5)).collect();
        let columnar_batch = TupleBatch::from_columns(
            crate::column::ColumnSet::from_rows(&rows).expect("uniform rows"),
        );
        let hashes: Vec<u64> = rows.iter().map(|t| t.get(0).stable_hash()).collect();
        let mut sink = VecEmitter::default();

        // Per-tuple reference for the partial layer.
        let mut reference = GroupByPartial::new(0, 1, AggKind::Avg);
        for r in &rows {
            reference.process(r.clone(), 0, &mut sink);
        }
        // Columnar absorb.
        let mut col = GroupByPartial::new(0, 1, AggKind::Avg);
        col.process_batch(&columnar_batch, 0, &mut sink);
        // Shipped-hash absorb (exchange partitioned on the group key).
        let mut shipped = GroupByPartial::new(0, 1, AggKind::Avg);
        shipped.process_batch_hashed(&columnar_batch, 0, &hashes, 0, &mut sink);
        // Wrong shipped key must fall back to local hashing, not misuse
        // the foreign column.
        let mut wrong_key = GroupByPartial::new(0, 1, AggKind::Avg);
        wrong_key.process_batch_hashed(&columnar_batch, 1, &hashes, 0, &mut sink);

        let (mut o1, mut o2, mut o3, mut o4) = (
            VecEmitter::default(),
            VecEmitter::default(),
            VecEmitter::default(),
            VecEmitter::default(),
        );
        reference.finish(&mut o1);
        col.finish(&mut o2);
        shipped.finish(&mut o3);
        wrong_key.finish(&mut o4);
        assert_eq!(o1.0, o2.0);
        assert_eq!(o1.0, o3.0);
        assert_eq!(o1.0, o4.0);

        // Final layer: feed the partials through per-tuple vs columnar
        // vs shipped-hash combine and compare the finished output.
        let partials = o1.0;
        let part_hashes: Vec<u64> =
            partials.iter().map(|t| t.get(0).stable_hash()).collect();
        let part_batch = TupleBatch::from_columns(
            crate::column::ColumnSet::from_rows(&partials).expect("uniform rows"),
        );
        let mut f_ref = GroupByFinal::new(AggKind::Avg);
        for t in &partials {
            f_ref.process(t.clone(), 0, &mut sink);
        }
        let mut f_col = GroupByFinal::new(AggKind::Avg);
        f_col.process_batch(&part_batch, 0, &mut sink);
        let mut f_shipped = GroupByFinal::new(AggKind::Avg);
        f_shipped.process_batch_hashed(&part_batch, 0, &part_hashes, 0, &mut sink);
        let (mut fo1, mut fo2, mut fo3) = (
            VecEmitter::default(),
            VecEmitter::default(),
            VecEmitter::default(),
        );
        f_ref.finish(&mut fo1);
        f_col.finish(&mut fo2);
        f_shipped.finish(&mut fo3);
        assert_eq!(fo1.0, fo2.0);
        assert_eq!(fo1.0, fo3.0);
    }

    #[test]
    fn snapshot_restore_roundtrip() {
        let mut p = GroupByPartial::new(0, 1, AggKind::Sum);
        let mut o = VecEmitter::default();
        p.process(t2(1, 2.5), 0, &mut o);
        let snap = p.snapshot();
        let mut q = GroupByPartial::new(0, 1, AggKind::Sum);
        q.restore(snap);
        q.process(t2(1, 2.5), 0, &mut o);
        let mut out = VecEmitter::default();
        q.finish(&mut out);
        assert_eq!(out.0[0].get(1).as_float(), Some(5.0));
    }

    #[test]
    fn groupby_is_mutable_state() {
        assert!(GroupByPartial::new(0, 1, AggKind::Sum).state_mutable());
        assert!(GroupByFinal::new(AggKind::Sum).state_mutable());
    }

    // ---- out-of-core ----

    fn tiny_ctx(limit: u64) -> SpillCtx {
        let mut cfg = Config::for_tests();
        cfg.memory_budget_bytes = limit;
        SpillCtx::new(&cfg)
    }

    #[test]
    fn spilled_two_layer_matches_unbounded() {
        for kind in [AggKind::Count, AggKind::Sum, AggKind::Min, AggKind::Max, AggKind::Avg] {
            let rows: Vec<Tuple> = (0..500).map(|i| t2(i % 43, i as f64 * 0.25)).collect();
            let unbounded = run_two_layer(kind, rows.clone());
            let ctx = tiny_ctx(256); // far below resident group state
            let spilled = run_two_layer_ctx(kind, rows, Some(&ctx));
            assert_eq!(spilled, unbounded, "kind {kind:?}");
            let stats = ctx.counters.snapshot(&ctx.budget);
            assert!(stats.bytes_spilled > 0, "tiny budget must spill");
        }
    }

    #[test]
    fn spilled_snapshot_restores_byte_exact() {
        let rows: Vec<Tuple> = (0..400).map(|i| t2(i % 31, i as f64)).collect();
        let unbounded = run_two_layer(AggKind::Sum, rows.clone());
        let ctx = tiny_ctx(256);
        let mut p = GroupByPartial::new(0, 1, AggKind::Sum);
        p.attach_spill(&ctx);
        let mut o = VecEmitter::default();
        for t in rows {
            p.process(t, 0, &mut o);
        }
        let snap = p.snapshot();
        assert!(!snap.spill.is_empty(), "manifest carries spilled partitions");
        // Post-snapshot absorbs must be truncated away by restore.
        p.process(t2(999, 1e9), 0, &mut o);
        let mut q = GroupByPartial::new(0, 1, AggKind::Sum);
        q.attach_spill(&ctx);
        q.restore(snap);
        let mut o1 = VecEmitter::default();
        q.finish(&mut o1);
        let mut f = GroupByFinal::new(AggKind::Sum);
        let mut o2 = VecEmitter::default();
        for t in o1.0 {
            f.process(t, 0, &mut o2);
        }
        f.finish(&mut o2);
        let got: HashMap<i64, f64> = o2
            .0
            .iter()
            .map(|t| (t.get(0).as_int().unwrap(), t.get(1).as_float().unwrap()))
            .collect();
        assert_eq!(got, unbounded);
    }

    #[test]
    fn spilled_extract_sees_all_groups() {
        let ctx = tiny_ctx(128);
        let mut p = GroupByPartial::new(0, 1, AggKind::Count);
        p.attach_spill(&ctx);
        let mut o = VecEmitter::default();
        for i in 0..200 {
            p.process(t2(i % 50, 1.0), 0, &mut o);
        }
        assert!(p.spill.has_files(), "must have spilled");
        let st = p.extract_state(None, false);
        assert_eq!(st.keyed_aggs.len(), 50, "extraction sees spilled + resident groups");
        assert_eq!(p.state_size(), 0);
    }

    #[test]
    fn foreign_groups_never_spill() {
        let ctx = tiny_ctx(64);
        // Worker 0 of 4: ~3/4 of groups are foreign (held for other
        // owners) and must stay resident for scattered_parts.
        let mut f = GroupByFinal::new_partitioned(AggKind::Sum, 0, 4);
        f.attach_spill(&ctx);
        let mut o = VecEmitter::default();
        for i in 0..200 {
            f.process(t2(i % 40, 1.0), 0, &mut o);
        }
        let shipped = f.scattered_parts();
        let shipped_groups: usize = shipped.iter().map(|(_, s)| s.keyed_aggs.len()).sum();
        assert!(shipped_groups > 0, "foreign groups ship from memory");
        assert!(
            shipped.iter().all(|(owner, _)| *owner != 0),
            "only foreign owners receive scattered parts"
        );
    }
}

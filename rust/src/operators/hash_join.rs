//! Two-phase hash join (§2.4.3 category 3, §4.2).
//!
//! Port 0 is the **build** input (blocking: no output until its entire
//! input is processed); port 1 is the **probe** input. Each worker
//! performs both phases (Fig. 4.3).
//!
//! State mutability (Table 3.1): the build phase is *mutable* (every
//! build tuple mutates the hash table); the probe phase is *immutable*
//! (probe tuples read it). Reshape therefore **replicates** hash-table
//! entries to helpers during probe-phase mitigation (Fig. 3.10 branch
//! (a)) and uses marker-synchronized moves during build-phase SBK.
//!
//! Early-probe handling: in strict mode (Maestro's premise, Fig. 4.1) a
//! probe tuple arriving before build EOF is an error; in buffering mode
//! (default) such tuples are buffered and replayed at build EOF — the
//! memory cost Maestro's materialization planning avoids.

use crate::engine::operator::{Emitter, OpState, Operator};
use crate::tuple::{Tuple, TupleBatch};
use std::collections::HashMap;

fn busy_spin(ns: u64) {
    let t0 = std::time::Instant::now();
    while (t0.elapsed().as_nanos() as u64) < ns {
        std::hint::spin_loop();
    }
}

/// Build port index.
pub const BUILD: usize = 0;
/// Probe port index.
pub const PROBE: usize = 1;

pub struct HashJoin {
    /// Key field in build tuples.
    pub build_key: usize,
    /// Key field in probe tuples.
    pub probe_key: usize,
    /// Hash table: key hash → build tuples.
    table: HashMap<u64, Vec<Tuple>>,
    build_done: bool,
    /// Probe tuples that arrived before build EOF (buffering mode).
    early_probe: Vec<Tuple>,
    /// Error on early probe input instead of buffering.
    pub strict: bool,
    /// Set when a strict-mode violation occurred (surfaced in stats).
    pub violated: bool,
    /// Artificial per-probe-tuple cost in nanoseconds (0 = none). The
    /// skew experiments assume "the join operator is the bottleneck"
    /// (§3.3.1); this models the paper's expensive join workers.
    pub probe_cost_ns: u64,
    tuples_in_state: usize,
}

impl HashJoin {
    pub fn new(build_key: usize, probe_key: usize) -> HashJoin {
        HashJoin {
            build_key,
            probe_key,
            table: HashMap::new(),
            build_done: false,
            early_probe: Vec::new(),
            strict: false,
            violated: false,
            probe_cost_ns: 0,
            tuples_in_state: 0,
        }
    }

    pub fn strict(mut self) -> HashJoin {
        self.strict = true;
        self
    }

    /// Builder: artificial per-probe-tuple cost.
    pub fn with_probe_cost(mut self, ns: u64) -> HashJoin {
        self.probe_cost_ns = ns;
        self
    }

    fn probe_one(&self, t: &Tuple, out: &mut dyn Emitter) {
        let h = t.get(self.probe_key).stable_hash();
        if let Some(matches) = self.table.get(&h) {
            for b in matches {
                out.emit(b.concat(t));
            }
        }
    }

    /// Probe a whole batch off a precomputed hash column (shipped by
    /// the sender or hashed here with the typed column kernel). Rows
    /// materialize lazily: a miss never touches the row view, so a
    /// selective probe of a columnar batch stays column-only.
    fn probe_hashed(&self, batch: &TupleBatch, hashes: &[u64], out: &mut dyn Emitter) {
        for (i, &h) in hashes.iter().enumerate() {
            if let Some(matches) = self.table.get(&h) {
                let t = batch.get(i);
                for b in matches {
                    out.emit(b.concat(t));
                }
            }
        }
    }

    /// Bulk build insert off a precomputed hash column.
    fn build_hashed(&mut self, batch: &TupleBatch, hashes: &[u64]) {
        for (i, &h) in hashes.iter().enumerate() {
            self.table.entry(h).or_default().push(batch.get(i).clone());
        }
        self.tuples_in_state += batch.len();
    }

    /// Hash the key column of a columnar batch with the typed
    /// [`crate::column::Column::hash_range`] kernel. `None` for
    /// row-major batches or out-of-range fields.
    fn column_hashes(batch: &TupleBatch, field: usize) -> Option<Vec<u64>> {
        let cv = batch.columns()?;
        let col = cv.set.cols.get(field)?;
        let mut hashes = Vec::new();
        col.hash_range(cv.start, cv.end, &mut hashes);
        Some(hashes)
    }
}

impl Operator for HashJoin {
    fn name(&self) -> &str {
        "hash_join"
    }

    fn num_ports(&self) -> usize {
        2
    }

    fn blocking_ports(&self) -> Vec<usize> {
        vec![BUILD]
    }

    fn process(&mut self, t: Tuple, port: usize, out: &mut dyn Emitter) {
        match port {
            BUILD => {
                let h = t.get(self.build_key).stable_hash();
                self.table.entry(h).or_default().push(t);
                self.tuples_in_state += 1;
            }
            PROBE => {
                if self.probe_cost_ns > 0 {
                    busy_spin(self.probe_cost_ns);
                }
                if self.build_done {
                    self.probe_one(&t, out);
                } else if self.strict {
                    // The Fig. 4.1 exception: probe before build EOF.
                    self.violated = true;
                } else {
                    self.early_probe.push(t);
                }
            }
            _ => unreachable!("hash join has 2 ports"),
        }
    }

    /// Batched probe: once the build side is complete, probe tuples are
    /// read straight out of the shared batch — no per-tuple clone, one
    /// spin covering the whole chunk's modeled cost. Columnar batches
    /// hash the key column with the typed kernel and only materialize
    /// rows on a match. Build input and pre-build-EOF probes fall back
    /// to the per-tuple path (they take ownership / buffer).
    fn process_batch(&mut self, batch: &TupleBatch, port: usize, out: &mut dyn Emitter) {
        if port == PROBE && self.build_done {
            if self.probe_cost_ns > 0 {
                busy_spin(self.probe_cost_ns * batch.len() as u64);
            }
            if let Some(hashes) = Self::column_hashes(batch, self.probe_key) {
                self.probe_hashed(batch, &hashes, out);
                return;
            }
            for t in batch.iter() {
                self.probe_one(t, out);
            }
            return;
        }
        if port == BUILD {
            if let Some(hashes) = Self::column_hashes(batch, self.build_key) {
                self.build_hashed(batch, &hashes);
                return;
            }
        }
        for t in batch.iter() {
            self.process(t.clone(), port, out);
        }
    }

    /// Shipped-hash fast path: the exchange already hashed the
    /// partitioning key of every tuple in the batch; when that key is
    /// this side's join key, build inserts and probe lookups reuse the
    /// column verbatim — zero hashing on this worker.
    fn process_batch_hashed(
        &mut self,
        batch: &TupleBatch,
        key: usize,
        hashes: &[u64],
        port: usize,
        out: &mut dyn Emitter,
    ) {
        match port {
            PROBE if self.build_done && key == self.probe_key => {
                if self.probe_cost_ns > 0 {
                    busy_spin(self.probe_cost_ns * batch.len() as u64);
                }
                self.probe_hashed(batch, hashes, out);
            }
            BUILD if key == self.build_key => {
                self.build_hashed(batch, hashes);
            }
            _ => self.process_batch(batch, port, out),
        }
    }

    fn finish_port(&mut self, port: usize, out: &mut dyn Emitter) {
        if port == BUILD {
            self.build_done = true;
            // Replay buffered probe input.
            let buffered = std::mem::take(&mut self.early_probe);
            for t in &buffered {
                self.probe_one(t, out);
            }
        }
    }

    fn snapshot(&self) -> OpState {
        let mut s = OpState::default();
        s.keyed_tuples = self.table.clone();
        s.counters.insert("build_done".into(), self.build_done as i64);
        if !self.early_probe.is_empty() {
            s.keyed_tuples
                .entry(u64::MAX) // sentinel scope for the early-probe buffer
                .or_default()
                .extend(self.early_probe.iter().cloned());
        }
        s
    }

    fn restore(&mut self, mut s: OpState) {
        self.early_probe = s.keyed_tuples.remove(&u64::MAX).unwrap_or_default();
        self.build_done = s.counters.get("build_done").copied().unwrap_or(0) != 0;
        self.tuples_in_state = s.keyed_tuples.values().map(Vec::len).sum();
        self.table = s.keyed_tuples;
    }

    fn state_size(&self) -> usize {
        self.tuples_in_state
    }

    fn extract_state(&mut self, keys: Option<&[u64]>, replicate: bool) -> OpState {
        let mut out = OpState::default();
        match keys {
            None => {
                // Whole-table: probe-phase SBR replication.
                out.keyed_tuples = self.table.clone();
                if !replicate {
                    self.table.clear();
                    self.tuples_in_state = 0;
                }
            }
            Some(ks) => {
                for k in ks {
                    if replicate {
                        if let Some(v) = self.table.get(k) {
                            out.keyed_tuples.insert(*k, v.clone());
                        }
                    } else if let Some(v) = self.table.remove(k) {
                        self.tuples_in_state -= v.len();
                        out.keyed_tuples.insert(*k, v);
                    }
                }
            }
        }
        out
    }

    fn merge_state(&mut self, s: OpState) {
        for (k, mut v) in s.keyed_tuples {
            if k == u64::MAX {
                continue;
            }
            self.tuples_in_state += v.len();
            self.table.entry(k).or_default().append(&mut v);
        }
        // A helper receiving probe-phase state is by definition past
        // build (the skewed worker only migrates state when its own
        // build phase is complete).
        self.build_done = true;
    }

    fn state_mutable(&self) -> bool {
        // Mutability is per-phase (§3.5.1).
        !self.build_done
    }

    /// Elastic-scale shard install. Unlike [`Operator::merge_state`]
    /// (Reshape probe-phase migration, which implies the donor passed
    /// build EOF) a re-hashed shard carries no phase information: keep
    /// this worker's own phase, so a mid-build scale does not start
    /// probing an incomplete table. (A scale-spawned worker reaches
    /// `build_done` through its own seeded EOF accounting.)
    fn install_state(&mut self, s: OpState) {
        for (k, mut v) in s.keyed_tuples {
            if k == u64::MAX {
                continue;
            }
            self.tuples_in_state += v.len();
            self.table.entry(k).or_default().append(&mut v);
        }
    }

    /// Broadcast-build replica (elastic scaling): the hash table plus
    /// the build-EOF flag, **without** the early-probe buffer — probe
    /// tuples are partitioned per worker, so replicating a donor's
    /// buffer would duplicate their join output on the new worker.
    fn replicate_broadcast_state(&self) -> OpState {
        let mut s = OpState::default();
        s.keyed_tuples = self.table.clone();
        s.counters.insert("build_done".into(), self.build_done as i64);
        s
    }

    /// Install a broadcast-build replica on a scale-spawned worker:
    /// unlike [`Operator::merge_state`] (Reshape probe-phase migration,
    /// which implies build EOF) this copies the donor's actual phase,
    /// so a mid-build scale-up keeps buffering early probes instead of
    /// probing an incomplete table.
    fn install_replica(&mut self, mut s: OpState) {
        self.build_done = s.counters.get("build_done").copied().unwrap_or(0) != 0;
        s.keyed_tuples.remove(&u64::MAX);
        self.tuples_in_state = s.keyed_tuples.values().map(Vec::len).sum();
        self.table = s.keyed_tuples;
    }

    /// The early-probe buffer is re-routable input, not keyed state:
    /// a retiring worker's buffered probes must reach the new probe
    /// owners, and a surviving worker's buffer must be re-hashed when
    /// the probe partitioning changes arity.
    fn drain_buffered_input(&mut self) -> Vec<(usize, Vec<Tuple>)> {
        if self.early_probe.is_empty() {
            Vec::new()
        } else {
            vec![(PROBE, std::mem::take(&mut self.early_probe))]
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::operator::VecEmitter;
    use crate::tuple::Value;

    fn kv(k: i64, v: &str) -> Tuple {
        Tuple::new(vec![Value::Int(k), Value::str(v)])
    }

    #[test]
    fn joins_matching_keys() {
        let mut j = HashJoin::new(0, 0);
        let mut out = VecEmitter::default();
        j.process(kv(1, "b1"), BUILD, &mut out);
        j.process(kv(2, "b2"), BUILD, &mut out);
        j.finish_port(BUILD, &mut out);
        j.process(kv(1, "p1"), PROBE, &mut out);
        j.process(kv(3, "p3"), PROBE, &mut out);
        assert_eq!(out.0.len(), 1);
        assert_eq!(out.0[0].arity(), 4);
        assert_eq!(out.0[0].get(1).as_str(), Some("b1"));
        assert_eq!(out.0[0].get(3).as_str(), Some("p1"));
    }

    #[test]
    fn duplicate_build_keys_multiply() {
        let mut j = HashJoin::new(0, 0);
        let mut out = VecEmitter::default();
        j.process(kv(1, "a"), BUILD, &mut out);
        j.process(kv(1, "b"), BUILD, &mut out);
        j.finish_port(BUILD, &mut out);
        j.process(kv(1, "p"), PROBE, &mut out);
        assert_eq!(out.0.len(), 2);
    }

    #[test]
    fn early_probe_buffered_and_replayed() {
        let mut j = HashJoin::new(0, 0);
        let mut out = VecEmitter::default();
        j.process(kv(1, "p-early"), PROBE, &mut out);
        assert_eq!(out.0.len(), 0);
        j.process(kv(1, "b"), BUILD, &mut out);
        j.finish_port(BUILD, &mut out);
        assert_eq!(out.0.len(), 1, "buffered probe replayed at build EOF");
    }

    #[test]
    fn strict_mode_flags_violation() {
        let mut j = HashJoin::new(0, 0).strict();
        let mut out = VecEmitter::default();
        j.process(kv(1, "p"), PROBE, &mut out);
        assert!(j.violated);
        assert_eq!(out.0.len(), 0);
    }

    #[test]
    fn batched_probe_matches_per_tuple() {
        let build: Vec<Tuple> = (0..5).map(|k| kv(k, "b")).collect();
        let probes: TupleBatch = (0..20).map(|i| kv(i % 7, "p")).collect();
        // Per-tuple reference.
        let mut a = HashJoin::new(0, 0);
        let mut out_a = VecEmitter::default();
        for b in &build {
            a.process(b.clone(), BUILD, &mut out_a);
        }
        a.finish_port(BUILD, &mut out_a);
        for p in probes.iter() {
            a.process(p.clone(), PROBE, &mut out_a);
        }
        // Batched probe.
        let mut b_join = HashJoin::new(0, 0);
        let mut out_b = VecEmitter::default();
        b_join.process_batch(&build.clone().into(), BUILD, &mut out_b);
        b_join.finish_port(BUILD, &mut out_b);
        b_join.process_batch(&probes, PROBE, &mut out_b);
        assert_eq!(out_a.0, out_b.0);
    }

    #[test]
    fn batched_early_probe_still_buffers() {
        let mut j = HashJoin::new(0, 0);
        let mut out = VecEmitter::default();
        let early: TupleBatch = vec![kv(1, "p-early")].into();
        j.process_batch(&early, PROBE, &mut out);
        assert_eq!(out.0.len(), 0);
        j.process(kv(1, "b"), BUILD, &mut out);
        j.finish_port(BUILD, &mut out);
        assert_eq!(out.0.len(), 1, "buffered probe replayed at build EOF");
    }

    #[test]
    fn columnar_and_shipped_hash_probe_match_per_tuple() {
        let build: Vec<Tuple> = (0..5).map(|k| kv(k, "b")).collect();
        let probe_rows: Vec<Tuple> = (0..20).map(|i| kv(i % 7, "p")).collect();
        // Per-tuple reference.
        let mut a = HashJoin::new(0, 0);
        let mut out_a = VecEmitter::default();
        for b in &build {
            a.process(b.clone(), BUILD, &mut out_a);
        }
        a.finish_port(BUILD, &mut out_a);
        for p in &probe_rows {
            a.process(p.clone(), PROBE, &mut out_a);
        }
        // Columnar build + probe.
        let col = |rows: &[Tuple]| {
            TupleBatch::from_columns(
                crate::column::ColumnSet::from_rows(rows).expect("uniform rows"),
            )
        };
        let mut b_join = HashJoin::new(0, 0);
        let mut out_b = VecEmitter::default();
        b_join.process_batch(&col(&build), BUILD, &mut out_b);
        b_join.finish_port(BUILD, &mut out_b);
        b_join.process_batch(&col(&probe_rows), PROBE, &mut out_b);
        assert_eq!(out_a.0, out_b.0);
        // Shipped-hash build + probe (hashes as the exchange computes
        // them: stable_hash of the key field).
        let hashes = |rows: &[Tuple]| -> Vec<u64> {
            rows.iter().map(|t| t.get(0).stable_hash()).collect()
        };
        let mut c_join = HashJoin::new(0, 0);
        let mut out_c = VecEmitter::default();
        let bb: TupleBatch = build.clone().into();
        c_join.process_batch_hashed(&bb, 0, &hashes(&build), BUILD, &mut out_c);
        c_join.finish_port(BUILD, &mut out_c);
        let pb: TupleBatch = probe_rows.clone().into();
        c_join.process_batch_hashed(&pb, 0, &hashes(&probe_rows), PROBE, &mut out_c);
        assert_eq!(out_a.0, out_c.0);
        // A shipped column for a *different* key must not be trusted.
        let mut d_join = HashJoin::new(1, 1);
        let mut out_d = VecEmitter::default();
        d_join.process_batch_hashed(&bb, 0, &hashes(&build), BUILD, &mut out_d);
        assert_eq!(d_join.state_size(), build.len(), "fell back to key-1 build");
    }

    #[test]
    fn mutability_flips_at_build_eof() {
        let mut j = HashJoin::new(0, 0);
        assert!(j.state_mutable(), "build phase is mutable");
        let mut out = VecEmitter::default();
        j.finish_port(BUILD, &mut out);
        assert!(!j.state_mutable(), "probe phase is immutable");
    }

    #[test]
    fn extract_replicate_keeps_original() {
        let mut j = HashJoin::new(0, 0);
        let mut out = VecEmitter::default();
        j.process(kv(1, "b"), BUILD, &mut out);
        j.finish_port(BUILD, &mut out);
        let k = Value::Int(1).stable_hash();
        let st = j.extract_state(Some(&[k]), true);
        assert_eq!(st.keyed_tuples[&k].len(), 1);
        // Original still probes fine.
        j.process(kv(1, "p"), PROBE, &mut out);
        assert_eq!(out.0.len(), 1);
    }

    #[test]
    fn extract_move_removes() {
        let mut j = HashJoin::new(0, 0);
        let mut out = VecEmitter::default();
        j.process(kv(1, "b"), BUILD, &mut out);
        j.finish_port(BUILD, &mut out);
        let k = Value::Int(1).stable_hash();
        let st = j.extract_state(Some(&[k]), false);
        assert_eq!(st.keyed_tuples[&k].len(), 1);
        j.process(kv(1, "p"), PROBE, &mut out);
        assert_eq!(out.0.len(), 0, "moved key no longer matches");
        assert_eq!(j.state_size(), 0);
    }

    #[test]
    fn helper_merge_enables_probing() {
        let mut skewed = HashJoin::new(0, 0);
        let mut helper = HashJoin::new(0, 0);
        let mut out = VecEmitter::default();
        skewed.process(kv(1, "b"), BUILD, &mut out);
        skewed.finish_port(BUILD, &mut out);
        let st = skewed.extract_state(None, true);
        helper.merge_state(st);
        helper.process(kv(1, "p"), PROBE, &mut out);
        assert_eq!(out.0.len(), 1);
    }

    #[test]
    fn snapshot_restore_roundtrip() {
        let mut j = HashJoin::new(0, 0);
        let mut out = VecEmitter::default();
        j.process(kv(1, "b"), BUILD, &mut out);
        j.process(kv(2, "p-early"), PROBE, &mut out);
        let snap = j.snapshot();
        let mut j2 = HashJoin::new(0, 0);
        j2.restore(snap);
        assert!(!j2.build_done);
        assert_eq!(j2.early_probe.len(), 1);
        j2.process(kv(2, "b2"), BUILD, &mut out);
        j2.finish_port(BUILD, &mut out);
        assert_eq!(out.0.len(), 1, "early probe matched post-restore build");
    }
}

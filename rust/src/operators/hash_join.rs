//! Two-phase hash join (§2.4.3 category 3, §4.2).
//!
//! Port 0 is the **build** input (blocking: no output until its entire
//! input is processed); port 1 is the **probe** input. Each worker
//! performs both phases (Fig. 4.3).
//!
//! State mutability (Table 3.1): the build phase is *mutable* (every
//! build tuple mutates the hash table); the probe phase is *immutable*
//! (probe tuples read it). Reshape therefore **replicates** hash-table
//! entries to helpers during probe-phase mitigation (Fig. 3.10 branch
//! (a)) and uses marker-synchronized moves during build-phase SBK.
//!
//! Early-probe handling: in strict mode (Maestro's premise, Fig. 4.1) a
//! probe tuple arriving before build EOF is an error; in buffering mode
//! (default) such tuples are buffered and replayed at build EOF — the
//! memory cost Maestro's materialization planning avoids.
//!
//! **Out-of-core** (Grace-style, see `docs/ARCHITECTURE.md`
//! "Out-of-core execution"): past the execution's memory budget the
//! join evicts whole depth-0 hash partitions
//! ([`crate::engine::spill::partition_of`]) of the build table to
//! spill files; probe tuples whose partition is spilled stream to
//! matching probe files, and at EOF each spilled partition pair is
//! joined from disk — recursively re-partitioned by the next hash
//! nibble while the build side still exceeds the budget. Results are
//! byte-identical to the in-memory path (the out-of-core equivalence
//! suite pins this); spilled build state re-enters memory before any
//! state extraction (migration/scale), and spilled *probe input*
//! returns through [`Operator::drain_buffered_input`] like the
//! early-probe buffer.

use crate::engine::operator::{Emitter, OpState, Operator};
use crate::engine::spill::{
    partition_of, read_slot_rows, rows_byte_size, MemLease, SpillCtx, SpillFile, SpillReader,
    SPILL_FANOUT, SPILL_MAX_DEPTH,
};
use crate::tuple::{Tuple, TupleBatch};
use std::collections::{BTreeSet, HashMap};

fn busy_spin(ns: u64) {
    let t0 = std::time::Instant::now();
    while (t0.elapsed().as_nanos() as u64) < ns {
        std::hint::spin_loop();
    }
}

/// Build port index.
pub const BUILD: usize = 0;
/// Probe port index.
pub const PROBE: usize = 1;

// Spill-slot tags (the join's stream kinds inside its manifest).
const TAG_BUILD: u32 = 0;
const TAG_PROBE: u32 = 1;
const TAG_EARLY: u32 = 2;

pub struct HashJoin {
    /// Key field in build tuples.
    pub build_key: usize,
    /// Key field in probe tuples.
    pub probe_key: usize,
    /// Hash table: key hash → build tuples (resident partitions only).
    table: HashMap<u64, Vec<Tuple>>,
    build_done: bool,
    /// Probe tuples that arrived before build EOF (buffering mode).
    early_probe: Vec<Tuple>,
    /// Error on early probe input instead of buffering.
    pub strict: bool,
    /// Set when a strict-mode violation occurred (surfaced in stats).
    pub violated: bool,
    /// Artificial per-probe-tuple cost in nanoseconds (0 = none). The
    /// skew experiments assume "the join operator is the bottleneck"
    /// (§3.3.1); this models the paper's expensive join workers.
    pub probe_cost_ns: u64,
    tuples_in_state: usize,

    // Out-of-core state (None / empty without an attached SpillCtx or
    // under an unbounded budget — the resident path is unchanged).
    spill: Option<SpillCtx>,
    lease: MemLease,
    /// Resident bytes currently charged: table rows + early-probe rows.
    resident_bytes: u64,
    /// Depth-0 partitions evicted to disk; build inserts and probe
    /// lookups for these route to files.
    spilled: BTreeSet<u64>,
    build_files: HashMap<u64, SpillFile>,
    probe_files: HashMap<u64, SpillFile>,
    early_file: Option<SpillFile>,
}

impl HashJoin {
    pub fn new(build_key: usize, probe_key: usize) -> HashJoin {
        HashJoin {
            build_key,
            probe_key,
            table: HashMap::new(),
            build_done: false,
            early_probe: Vec::new(),
            strict: false,
            violated: false,
            probe_cost_ns: 0,
            tuples_in_state: 0,
            spill: None,
            lease: MemLease::default(),
            resident_bytes: 0,
            spilled: BTreeSet::new(),
            build_files: HashMap::new(),
            probe_files: HashMap::new(),
            early_file: None,
        }
    }

    pub fn strict(mut self) -> HashJoin {
        self.strict = true;
        self
    }

    /// Builder: artificial per-probe-tuple cost.
    pub fn with_probe_cost(mut self, ns: u64) -> HashJoin {
        self.probe_cost_ns = ns;
        self
    }

    fn probe_one(&self, t: &Tuple, out: &mut dyn Emitter) {
        let h = t.get(self.probe_key).stable_hash();
        if let Some(matches) = self.table.get(&h) {
            for b in matches {
                out.emit(b.concat(t));
            }
        }
    }

    /// Probe a whole batch off a precomputed hash column (shipped by
    /// the sender or hashed here with the typed column kernel). Rows
    /// materialize lazily: a miss never touches the row view, so a
    /// selective probe of a columnar batch stays column-only. Probe
    /// rows belonging to spilled partitions stream to their partition
    /// file instead.
    fn probe_hashed(&mut self, batch: &TupleBatch, hashes: &[u64], out: &mut dyn Emitter) {
        if self.spilled.is_empty() {
            for (i, &h) in hashes.iter().enumerate() {
                if let Some(matches) = self.table.get(&h) {
                    let t = batch.get(i);
                    for b in matches {
                        out.emit(b.concat(t));
                    }
                }
            }
            return;
        }
        let mut to_file: HashMap<u64, Vec<Tuple>> = HashMap::new();
        for (i, &h) in hashes.iter().enumerate() {
            let p = partition_of(h, 0) as u64;
            if self.spilled.contains(&p) {
                to_file.entry(p).or_default().push(batch.get(i).clone());
            } else if let Some(matches) = self.table.get(&h) {
                let t = batch.get(i);
                for b in matches {
                    out.emit(b.concat(t));
                }
            }
        }
        let mut parts: Vec<u64> = to_file.keys().copied().collect();
        parts.sort_unstable();
        for p in parts {
            let rows = to_file.remove(&p).unwrap();
            self.probe_file(p).append(&rows);
        }
    }

    /// Bulk build insert off a precomputed hash column.
    fn build_hashed(&mut self, batch: &TupleBatch, hashes: &[u64]) {
        if self.spilled.is_empty() {
            for (i, &h) in hashes.iter().enumerate() {
                let t = batch.get(i).clone();
                self.resident_bytes += t.byte_size() as u64;
                self.table.entry(h).or_default().push(t);
            }
        } else {
            let mut to_file: HashMap<u64, Vec<Tuple>> = HashMap::new();
            for (i, &h) in hashes.iter().enumerate() {
                let t = batch.get(i).clone();
                let p = partition_of(h, 0) as u64;
                if self.spilled.contains(&p) {
                    to_file.entry(p).or_default().push(t);
                } else {
                    self.resident_bytes += t.byte_size() as u64;
                    self.table.entry(h).or_default().push(t);
                }
            }
            let mut parts: Vec<u64> = to_file.keys().copied().collect();
            parts.sort_unstable();
            for p in parts {
                let rows = to_file.remove(&p).unwrap();
                self.build_file(p).append(&rows);
            }
        }
        self.tuples_in_state += batch.len();
        self.lease.set(self.resident_bytes);
        self.maybe_spill();
    }

    /// Hash the key column of a columnar batch with the typed
    /// [`crate::column::Column::hash_range`] kernel. `None` for
    /// row-major batches or out-of-range fields.
    fn column_hashes(batch: &TupleBatch, field: usize) -> Option<Vec<u64>> {
        let cv = batch.columns()?;
        let col = cv.set.cols.get(field)?;
        let mut hashes = Vec::new();
        col.hash_range(cv.start, cv.end, &mut hashes);
        Some(hashes)
    }

    // ---- out-of-core plumbing ----

    fn build_file(&mut self, p: u64) -> &mut SpillFile {
        let ctx = self.spill.as_ref().expect("spill ctx attached");
        self.build_files
            .entry(p)
            .or_insert_with(|| SpillFile::create(ctx, TAG_BUILD, p, 0))
    }

    fn probe_file(&mut self, p: u64) -> &mut SpillFile {
        let ctx = self.spill.as_ref().expect("spill ctx attached");
        self.probe_files
            .entry(p)
            .or_insert_with(|| SpillFile::create(ctx, TAG_PROBE, p, 0))
    }

    /// One build-tuple insert, routed past the budget: spilled
    /// partitions append straight to their file (per-key insertion
    /// order is preserved — evicted rows were written in key order at
    /// eviction time, later arrivals append after).
    fn insert_build(&mut self, h: u64, t: Tuple) {
        self.tuples_in_state += 1;
        let p = partition_of(h, 0) as u64;
        if self.spilled.contains(&p) {
            let rows = [t];
            self.build_file(p).append(&rows);
        } else {
            self.resident_bytes += t.byte_size() as u64;
            self.table.entry(h).or_default().push(t);
            self.lease.set(self.resident_bytes);
            self.maybe_spill();
        }
    }

    /// While over budget, evict the largest resident build partition
    /// (then the early-probe buffer) to disk.
    fn maybe_spill(&mut self) {
        let Some(ctx) = self.spill.clone() else { return };
        if !ctx.budget.over() {
            return;
        }
        while ctx.budget.over() && self.evict_largest_partition(&ctx) {}
        if ctx.budget.over() && !self.early_probe.is_empty() {
            let rows = std::mem::take(&mut self.early_probe);
            self.resident_bytes -= rows_byte_size(&rows);
            let f = self
                .early_file
                .get_or_insert_with(|| SpillFile::create(&ctx, TAG_EARLY, 0, 0));
            f.append(&rows);
            self.lease.set(self.resident_bytes);
        }
    }

    fn evict_largest_partition(&mut self, ctx: &SpillCtx) -> bool {
        let mut sizes: HashMap<u64, u64> = HashMap::new();
        for (k, v) in &self.table {
            *sizes.entry(partition_of(*k, 0) as u64).or_insert(0) += rows_byte_size(v);
        }
        let Some((&p, _)) = sizes
            .iter()
            .max_by_key(|&(&p, &b)| (b, std::cmp::Reverse(p)))
        else {
            return false;
        };
        let mut keys: Vec<u64> = self
            .table
            .keys()
            .copied()
            .filter(|k| partition_of(*k, 0) as u64 == p)
            .collect();
        keys.sort_unstable();
        for k in keys {
            let rows = self.table.remove(&k).unwrap();
            self.resident_bytes -= rows_byte_size(&rows);
            self.build_file(p).append(&rows);
        }
        self.spilled.insert(p);
        ctx.counters.add_partition();
        self.lease.set(self.resident_bytes);
        true
    }

    /// Read every spilled build partition back into the resident table
    /// (state extraction paths: migration/scale work on resident
    /// state). Files stay on disk, orphaned, until the execution's
    /// spill directory is reclaimed at teardown.
    fn unspill_build(&mut self) {
        let Some(ctx) = self.spill.clone() else { return };
        let mut parts: Vec<u64> = self.build_files.keys().copied().collect();
        parts.sort_unstable();
        for p in parts {
            let f = self.build_files.remove(&p).unwrap();
            for t in read_slot_rows(&ctx, &f.slot()) {
                let h = t.get(self.build_key).stable_hash();
                self.resident_bytes += t.byte_size() as u64;
                self.table.entry(h).or_default().push(t);
            }
        }
        self.spilled.clear();
        self.lease.set(self.resident_bytes);
    }

    /// Dispatch one post-build-EOF probe tuple: spilled partition →
    /// probe file; resident → immediate probe.
    fn dispatch_probe(&mut self, t: &Tuple, out: &mut dyn Emitter) {
        let h = t.get(self.probe_key).stable_hash();
        let p = partition_of(h, 0) as u64;
        if self.spilled.contains(&p) {
            let rows = [t.clone()];
            self.probe_file(p).append(&rows);
        } else if let Some(matches) = self.table.get(&h) {
            for b in matches {
                out.emit(b.concat(t));
            }
        }
    }

    /// Join one spilled partition pair from disk, recursively
    /// re-partitioning by the next hash nibble while the build side
    /// still exceeds the budget (classic Grace recursion; bounded by
    /// [`SPILL_MAX_DEPTH`], past which the partition is processed in
    /// memory regardless — correctness over strictness).
    fn join_partition(
        &mut self,
        ctx: &SpillCtx,
        build: crate::engine::spill::SpillSlot,
        probe: Option<crate::engine::spill::SpillSlot>,
        depth: u32,
        out: &mut dyn Emitter,
    ) {
        ctx.counters.observe_depth(depth);
        let limit = ctx.budget.limit();
        if limit > 0 && build.bytes > limit && depth < SPILL_MAX_DEPTH {
            let next = depth + 1;
            let mut sub_build: Vec<Option<SpillFile>> =
                (0..SPILL_FANOUT).map(|_| None).collect();
            let mut sub_probe: Vec<Option<SpillFile>> =
                (0..SPILL_FANOUT).map(|_| None).collect();
            let mut repartition =
                |slot: &crate::engine::spill::SpillSlot,
                 key: usize,
                 tag: u32,
                 subs: &mut Vec<Option<SpillFile>>| {
                    let mut reader = SpillReader::open(ctx, slot);
                    while let Some(rows) = reader.next_rows() {
                        let mut buckets: Vec<Vec<Tuple>> =
                            (0..SPILL_FANOUT).map(|_| Vec::new()).collect();
                        for t in rows {
                            let h = t.get(key).stable_hash();
                            buckets[partition_of(h, next)].push(t);
                        }
                        for (i, b) in buckets.into_iter().enumerate() {
                            if b.is_empty() {
                                continue;
                            }
                            let scope = (slot.scope << 4) | i as u64;
                            let f = subs[i].get_or_insert_with(|| {
                                SpillFile::create(ctx, tag, scope, 0)
                            });
                            f.append(&b);
                        }
                    }
                };
            repartition(&build, self.build_key, TAG_BUILD, &mut sub_build);
            if let Some(p) = &probe {
                repartition(p, self.probe_key, TAG_PROBE, &mut sub_probe);
            }
            for i in 0..SPILL_FANOUT {
                let Some(bf) = sub_build[i].take() else { continue };
                ctx.counters.add_partition();
                let pf = sub_probe[i].take().map(|f| f.slot());
                self.join_partition(ctx, bf.slot(), pf, next, out);
            }
            // Probe rows with no build rows in their sub-partition can
            // match nothing — dropped with their files.
            return;
        }
        // Terminal: load the build side into a map, stream the probe
        // side frame by frame. The load is charged against the budget
        // for the duration (RAII lease).
        let rows = read_slot_rows(ctx, &build);
        let mut lease = MemLease::new(ctx.budget.clone());
        lease.set(rows_byte_size(&rows));
        let mut map: HashMap<u64, Vec<Tuple>> = HashMap::new();
        for t in rows {
            map.entry(t.get(self.build_key).stable_hash()).or_default().push(t);
        }
        if let Some(p) = probe {
            let mut reader = SpillReader::open(ctx, &p);
            while let Some(rows) = reader.next_rows() {
                for t in rows {
                    // probe_cost_ns was already paid when the tuple
                    // arrived and was routed to the file — no re-spin.
                    if let Some(matches) = map.get(&t.get(self.probe_key).stable_hash()) {
                        for b in matches {
                            out.emit(b.concat(&t));
                        }
                    }
                }
            }
        }
    }
}

impl Operator for HashJoin {
    fn name(&self) -> &str {
        "hash_join"
    }

    fn num_ports(&self) -> usize {
        2
    }

    fn blocking_ports(&self) -> Vec<usize> {
        vec![BUILD]
    }

    fn attach_spill(&mut self, ctx: &SpillCtx) {
        self.spill = Some(ctx.clone());
        self.lease = MemLease::new(ctx.budget.clone());
    }

    fn process(&mut self, t: Tuple, port: usize, out: &mut dyn Emitter) {
        match port {
            BUILD => {
                let h = t.get(self.build_key).stable_hash();
                self.insert_build(h, t);
            }
            PROBE => {
                if self.probe_cost_ns > 0 {
                    busy_spin(self.probe_cost_ns);
                }
                if self.build_done {
                    if self.spilled.is_empty() {
                        self.probe_one(&t, out);
                    } else {
                        self.dispatch_probe(&t, out);
                    }
                } else if self.strict {
                    // The Fig. 4.1 exception: probe before build EOF.
                    self.violated = true;
                } else {
                    self.resident_bytes += t.byte_size() as u64;
                    self.early_probe.push(t);
                    self.lease.set(self.resident_bytes);
                    self.maybe_spill();
                }
            }
            _ => unreachable!("hash join has 2 ports"),
        }
    }

    /// Batched probe: once the build side is complete, probe tuples are
    /// read straight out of the shared batch — no per-tuple clone, one
    /// spin covering the whole chunk's modeled cost. Columnar batches
    /// hash the key column with the typed kernel and only materialize
    /// rows on a match. Build input and pre-build-EOF probes fall back
    /// to the per-tuple path (they take ownership / buffer).
    fn process_batch(&mut self, batch: &TupleBatch, port: usize, out: &mut dyn Emitter) {
        if port == PROBE && self.build_done {
            if self.probe_cost_ns > 0 {
                busy_spin(self.probe_cost_ns * batch.len() as u64);
            }
            if let Some(hashes) = Self::column_hashes(batch, self.probe_key) {
                self.probe_hashed(batch, &hashes, out);
                return;
            }
            for t in batch.iter() {
                if self.spilled.is_empty() {
                    self.probe_one(t, out);
                } else {
                    self.dispatch_probe(t, out);
                }
            }
            return;
        }
        if port == BUILD {
            if let Some(hashes) = Self::column_hashes(batch, self.build_key) {
                self.build_hashed(batch, &hashes);
                return;
            }
        }
        for t in batch.iter() {
            self.process(t.clone(), port, out);
        }
    }

    /// Shipped-hash fast path: the exchange already hashed the
    /// partitioning key of every tuple in the batch; when that key is
    /// this side's join key, build inserts and probe lookups reuse the
    /// column verbatim — zero hashing on this worker.
    fn process_batch_hashed(
        &mut self,
        batch: &TupleBatch,
        key: usize,
        hashes: &[u64],
        port: usize,
        out: &mut dyn Emitter,
    ) {
        match port {
            PROBE if self.build_done && key == self.probe_key => {
                if self.probe_cost_ns > 0 {
                    busy_spin(self.probe_cost_ns * batch.len() as u64);
                }
                self.probe_hashed(batch, hashes, out);
            }
            BUILD if key == self.build_key => {
                self.build_hashed(batch, hashes);
            }
            _ => self.process_batch(batch, port, out),
        }
    }

    fn finish_port(&mut self, port: usize, out: &mut dyn Emitter) {
        if port == BUILD {
            self.build_done = true;
            // Replay buffered probe input: the spilled early buffer
            // first (older rows), then the resident one. Replayed
            // tuples route like live probes — spilled partitions go to
            // their probe file for the at-EOF disk join.
            if let Some(f) = self.early_file.take() {
                let ctx = self.spill.clone().expect("spill ctx attached");
                for t in read_slot_rows(&ctx, &f.slot()) {
                    self.dispatch_probe(&t, out);
                }
            }
            let buffered = std::mem::take(&mut self.early_probe);
            self.resident_bytes -= rows_byte_size(&buffered);
            self.lease.set(self.resident_bytes);
            for t in &buffered {
                if self.spilled.is_empty() {
                    self.probe_one(t, out);
                } else {
                    self.dispatch_probe(t, out);
                }
            }
        }
    }

    fn finish(&mut self, out: &mut dyn Emitter) {
        if self.spilled.is_empty() {
            return;
        }
        let ctx = self.spill.clone().expect("spill ctx attached");
        let parts: Vec<u64> = self.spilled.iter().copied().collect();
        for p in parts {
            let Some(bf) = self.build_files.remove(&p) else { continue };
            let pf = self.probe_files.remove(&p).map(|f| f.slot());
            self.join_partition(&ctx, bf.slot(), pf, 0, out);
        }
        self.spilled.clear();
    }

    fn snapshot(&self) -> OpState {
        let mut s = OpState::default();
        s.keyed_tuples = self.table.clone();
        s.counters.insert("build_done".into(), self.build_done as i64);
        if !self.early_probe.is_empty() {
            s.keyed_tuples
                .entry(u64::MAX) // sentinel scope for the early-probe buffer
                .or_default()
                .extend(self.early_probe.iter().cloned());
        }
        // Spill manifest: build/probe partition files + the early file.
        // Frames are flushed at append time, so the slots' byte lengths
        // are durable the instant this snapshot is taken.
        let mut parts: Vec<u64> = self.build_files.keys().copied().collect();
        parts.sort_unstable();
        for p in parts {
            s.spill.push(self.build_files[&p].slot());
        }
        let mut parts: Vec<u64> = self.probe_files.keys().copied().collect();
        parts.sort_unstable();
        for p in parts {
            s.spill.push(self.probe_files[&p].slot());
        }
        if let Some(f) = &self.early_file {
            s.spill.push(f.slot());
        }
        s
    }

    fn restore(&mut self, mut s: OpState) {
        self.early_probe = s.keyed_tuples.remove(&u64::MAX).unwrap_or_default();
        self.build_done = s.counters.get("build_done").copied().unwrap_or(0) != 0;
        self.tuples_in_state = s.keyed_tuples.values().map(Vec::len).sum();
        self.table = s.keyed_tuples;
        self.spilled.clear();
        self.build_files.clear();
        self.probe_files.clear();
        self.early_file = None;
        if !s.spill.is_empty() {
            let ctx = self.spill.clone().expect("spill ctx attached before restore");
            for slot in s.spill.drain(..) {
                match slot.tag {
                    TAG_BUILD => {
                        self.tuples_in_state += slot.rows as usize;
                        self.spilled.insert(slot.scope);
                        self.build_files
                            .insert(slot.scope, SpillFile::reopen(&ctx, &slot));
                    }
                    TAG_PROBE => {
                        self.probe_files
                            .insert(slot.scope, SpillFile::reopen(&ctx, &slot));
                    }
                    TAG_EARLY => {
                        self.early_file = Some(SpillFile::reopen(&ctx, &slot));
                    }
                    _ => unreachable!("unknown hash-join spill tag"),
                }
            }
        }
        self.resident_bytes = self.table.values().map(|v| rows_byte_size(v)).sum::<u64>()
            + rows_byte_size(&self.early_probe);
        self.lease.set(self.resident_bytes);
    }

    fn state_size(&self) -> usize {
        self.tuples_in_state
    }

    fn extract_state(&mut self, keys: Option<&[u64]>, replicate: bool) -> OpState {
        // Migration/scale extraction works on resident state: read any
        // spilled build partitions back first (the files stay on disk,
        // orphaned, until the execution-level directory cleanup).
        self.unspill_build();
        let mut out = OpState::default();
        match keys {
            None => {
                // Whole-table: probe-phase SBR replication.
                out.keyed_tuples = self.table.clone();
                if !replicate {
                    self.table.clear();
                    self.tuples_in_state = 0;
                    self.resident_bytes = rows_byte_size(&self.early_probe);
                    self.lease.set(self.resident_bytes);
                }
            }
            Some(ks) => {
                for k in ks {
                    if replicate {
                        if let Some(v) = self.table.get(k) {
                            out.keyed_tuples.insert(*k, v.clone());
                        }
                    } else if let Some(v) = self.table.remove(k) {
                        self.tuples_in_state -= v.len();
                        self.resident_bytes -= rows_byte_size(&v);
                        out.keyed_tuples.insert(*k, v);
                    }
                }
                self.lease.set(self.resident_bytes);
            }
        }
        out
    }

    fn merge_state(&mut self, s: OpState) {
        for (k, v) in s.keyed_tuples {
            if k == u64::MAX {
                continue;
            }
            for t in v {
                self.insert_build(k, t);
            }
        }
        // A helper receiving probe-phase state is by definition past
        // build (the skewed worker only migrates state when its own
        // build phase is complete).
        self.build_done = true;
    }

    fn state_mutable(&self) -> bool {
        // Mutability is per-phase (§3.5.1).
        !self.build_done
    }

    /// Elastic-scale shard install. Unlike [`Operator::merge_state`]
    /// (Reshape probe-phase migration, which implies the donor passed
    /// build EOF) a re-hashed shard carries no phase information: keep
    /// this worker's own phase, so a mid-build scale does not start
    /// probing an incomplete table. (A scale-spawned worker reaches
    /// `build_done` through its own seeded EOF accounting.)
    fn install_state(&mut self, s: OpState) {
        for (k, v) in s.keyed_tuples {
            if k == u64::MAX {
                continue;
            }
            for t in v {
                self.insert_build(k, t);
            }
        }
    }

    /// Broadcast-build replica (elastic scaling): the hash table plus
    /// the build-EOF flag, **without** the early-probe buffer — probe
    /// tuples are partitioned per worker, so replicating a donor's
    /// buffer would duplicate their join output on the new worker.
    /// Spilled build partitions are read (not moved) off disk so the
    /// replica is complete.
    fn replicate_broadcast_state(&self) -> OpState {
        let mut s = OpState::default();
        s.keyed_tuples = self.table.clone();
        if let Some(ctx) = &self.spill {
            let mut parts: Vec<u64> = self.build_files.keys().copied().collect();
            parts.sort_unstable();
            for p in parts {
                for t in read_slot_rows(ctx, &self.build_files[&p].slot()) {
                    let h = t.get(self.build_key).stable_hash();
                    s.keyed_tuples.entry(h).or_default().push(t);
                }
            }
        }
        s.counters.insert("build_done".into(), self.build_done as i64);
        s
    }

    /// Install a broadcast-build replica on a scale-spawned worker:
    /// unlike [`Operator::merge_state`] (Reshape probe-phase migration,
    /// which implies build EOF) this copies the donor's actual phase,
    /// so a mid-build scale-up keeps buffering early probes instead of
    /// probing an incomplete table.
    fn install_replica(&mut self, mut s: OpState) {
        self.build_done = s.counters.get("build_done").copied().unwrap_or(0) != 0;
        s.keyed_tuples.remove(&u64::MAX);
        self.tuples_in_state = s.keyed_tuples.values().map(Vec::len).sum();
        self.table = s.keyed_tuples;
        self.resident_bytes = self.table.values().map(|v| rows_byte_size(v)).sum::<u64>()
            + rows_byte_size(&self.early_probe);
        self.lease.set(self.resident_bytes);
    }

    /// The early-probe buffer — resident *and* spilled, plus any probe
    /// tuples parked in spilled-partition files — is re-routable input,
    /// not keyed state: a retiring worker's buffered probes must reach
    /// the new probe owners, and a surviving worker's buffer must be
    /// re-hashed when the probe partitioning changes arity.
    fn drain_buffered_input(&mut self) -> Vec<(usize, Vec<Tuple>)> {
        let mut rows = Vec::new();
        if let Some(ctx) = self.spill.clone() {
            if let Some(f) = self.early_file.take() {
                rows.extend(read_slot_rows(&ctx, &f.slot()));
            }
            let mut parts: Vec<u64> = self.probe_files.keys().copied().collect();
            parts.sort_unstable();
            for p in parts {
                let f = self.probe_files.remove(&p).unwrap();
                rows.extend(read_slot_rows(&ctx, &f.slot()));
            }
        }
        let resident = std::mem::take(&mut self.early_probe);
        self.resident_bytes -= rows_byte_size(&resident);
        self.lease.set(self.resident_bytes);
        rows.extend(resident);
        if rows.is_empty() {
            Vec::new()
        } else {
            vec![(PROBE, rows)]
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::engine::operator::VecEmitter;
    use crate::tuple::Value;

    fn kv(k: i64, v: &str) -> Tuple {
        Tuple::new(vec![Value::Int(k), Value::str(v)])
    }

    #[test]
    fn joins_matching_keys() {
        let mut j = HashJoin::new(0, 0);
        let mut out = VecEmitter::default();
        j.process(kv(1, "b1"), BUILD, &mut out);
        j.process(kv(2, "b2"), BUILD, &mut out);
        j.finish_port(BUILD, &mut out);
        j.process(kv(1, "p1"), PROBE, &mut out);
        j.process(kv(3, "p3"), PROBE, &mut out);
        assert_eq!(out.0.len(), 1);
        assert_eq!(out.0[0].arity(), 4);
        assert_eq!(out.0[0].get(1).as_str(), Some("b1"));
        assert_eq!(out.0[0].get(3).as_str(), Some("p1"));
    }

    #[test]
    fn duplicate_build_keys_multiply() {
        let mut j = HashJoin::new(0, 0);
        let mut out = VecEmitter::default();
        j.process(kv(1, "a"), BUILD, &mut out);
        j.process(kv(1, "b"), BUILD, &mut out);
        j.finish_port(BUILD, &mut out);
        j.process(kv(1, "p"), PROBE, &mut out);
        assert_eq!(out.0.len(), 2);
    }

    #[test]
    fn early_probe_buffered_and_replayed() {
        let mut j = HashJoin::new(0, 0);
        let mut out = VecEmitter::default();
        j.process(kv(1, "p-early"), PROBE, &mut out);
        assert_eq!(out.0.len(), 0);
        j.process(kv(1, "b"), BUILD, &mut out);
        j.finish_port(BUILD, &mut out);
        assert_eq!(out.0.len(), 1, "buffered probe replayed at build EOF");
    }

    #[test]
    fn strict_mode_flags_violation() {
        let mut j = HashJoin::new(0, 0).strict();
        let mut out = VecEmitter::default();
        j.process(kv(1, "p"), PROBE, &mut out);
        assert!(j.violated);
        assert_eq!(out.0.len(), 0);
    }

    #[test]
    fn batched_probe_matches_per_tuple() {
        let build: Vec<Tuple> = (0..5).map(|k| kv(k, "b")).collect();
        let probes: TupleBatch = (0..20).map(|i| kv(i % 7, "p")).collect();
        // Per-tuple reference.
        let mut a = HashJoin::new(0, 0);
        let mut out_a = VecEmitter::default();
        for b in &build {
            a.process(b.clone(), BUILD, &mut out_a);
        }
        a.finish_port(BUILD, &mut out_a);
        for p in probes.iter() {
            a.process(p.clone(), PROBE, &mut out_a);
        }
        // Batched probe.
        let mut b_join = HashJoin::new(0, 0);
        let mut out_b = VecEmitter::default();
        b_join.process_batch(&build.clone().into(), BUILD, &mut out_b);
        b_join.finish_port(BUILD, &mut out_b);
        b_join.process_batch(&probes, PROBE, &mut out_b);
        assert_eq!(out_a.0, out_b.0);
    }

    #[test]
    fn batched_early_probe_still_buffers() {
        let mut j = HashJoin::new(0, 0);
        let mut out = VecEmitter::default();
        let early: TupleBatch = vec![kv(1, "p-early")].into();
        j.process_batch(&early, PROBE, &mut out);
        assert_eq!(out.0.len(), 0);
        j.process(kv(1, "b"), BUILD, &mut out);
        j.finish_port(BUILD, &mut out);
        assert_eq!(out.0.len(), 1, "buffered probe replayed at build EOF");
    }

    #[test]
    fn columnar_and_shipped_hash_probe_match_per_tuple() {
        let build: Vec<Tuple> = (0..5).map(|k| kv(k, "b")).collect();
        let probe_rows: Vec<Tuple> = (0..20).map(|i| kv(i % 7, "p")).collect();
        // Per-tuple reference.
        let mut a = HashJoin::new(0, 0);
        let mut out_a = VecEmitter::default();
        for b in &build {
            a.process(b.clone(), BUILD, &mut out_a);
        }
        a.finish_port(BUILD, &mut out_a);
        for p in &probe_rows {
            a.process(p.clone(), PROBE, &mut out_a);
        }
        // Columnar build + probe.
        let col = |rows: &[Tuple]| {
            TupleBatch::from_columns(
                crate::column::ColumnSet::from_rows(rows).expect("uniform rows"),
            )
        };
        let mut b_join = HashJoin::new(0, 0);
        let mut out_b = VecEmitter::default();
        b_join.process_batch(&col(&build), BUILD, &mut out_b);
        b_join.finish_port(BUILD, &mut out_b);
        b_join.process_batch(&col(&probe_rows), PROBE, &mut out_b);
        assert_eq!(out_a.0, out_b.0);
        // Shipped-hash build + probe (hashes as the exchange computes
        // them: stable_hash of the key field).
        let hashes = |rows: &[Tuple]| -> Vec<u64> {
            rows.iter().map(|t| t.get(0).stable_hash()).collect()
        };
        let mut c_join = HashJoin::new(0, 0);
        let mut out_c = VecEmitter::default();
        let bb: TupleBatch = build.clone().into();
        c_join.process_batch_hashed(&bb, 0, &hashes(&build), BUILD, &mut out_c);
        c_join.finish_port(BUILD, &mut out_c);
        let pb: TupleBatch = probe_rows.clone().into();
        c_join.process_batch_hashed(&pb, 0, &hashes(&probe_rows), PROBE, &mut out_c);
        assert_eq!(out_a.0, out_c.0);
        // A shipped column for a *different* key must not be trusted.
        let mut d_join = HashJoin::new(1, 1);
        let mut out_d = VecEmitter::default();
        d_join.process_batch_hashed(&bb, 0, &hashes(&build), BUILD, &mut out_d);
        assert_eq!(d_join.state_size(), build.len(), "fell back to key-1 build");
    }

    #[test]
    fn mutability_flips_at_build_eof() {
        let mut j = HashJoin::new(0, 0);
        assert!(j.state_mutable(), "build phase is mutable");
        let mut out = VecEmitter::default();
        j.finish_port(BUILD, &mut out);
        assert!(!j.state_mutable(), "probe phase is immutable");
    }

    #[test]
    fn extract_replicate_keeps_original() {
        let mut j = HashJoin::new(0, 0);
        let mut out = VecEmitter::default();
        j.process(kv(1, "b"), BUILD, &mut out);
        j.finish_port(BUILD, &mut out);
        let k = Value::Int(1).stable_hash();
        let st = j.extract_state(Some(&[k]), true);
        assert_eq!(st.keyed_tuples[&k].len(), 1);
        // Original still probes fine.
        j.process(kv(1, "p"), PROBE, &mut out);
        assert_eq!(out.0.len(), 1);
    }

    #[test]
    fn extract_move_removes() {
        let mut j = HashJoin::new(0, 0);
        let mut out = VecEmitter::default();
        j.process(kv(1, "b"), BUILD, &mut out);
        j.finish_port(BUILD, &mut out);
        let k = Value::Int(1).stable_hash();
        let st = j.extract_state(Some(&[k]), false);
        assert_eq!(st.keyed_tuples[&k].len(), 1);
        j.process(kv(1, "p"), PROBE, &mut out);
        assert_eq!(out.0.len(), 0, "moved key no longer matches");
        assert_eq!(j.state_size(), 0);
    }

    #[test]
    fn helper_merge_enables_probing() {
        let mut skewed = HashJoin::new(0, 0);
        let mut helper = HashJoin::new(0, 0);
        let mut out = VecEmitter::default();
        skewed.process(kv(1, "b"), BUILD, &mut out);
        skewed.finish_port(BUILD, &mut out);
        let st = skewed.extract_state(None, true);
        helper.merge_state(st);
        helper.process(kv(1, "p"), PROBE, &mut out);
        assert_eq!(out.0.len(), 1);
    }

    #[test]
    fn snapshot_restore_roundtrip() {
        let mut j = HashJoin::new(0, 0);
        let mut out = VecEmitter::default();
        j.process(kv(1, "b"), BUILD, &mut out);
        j.process(kv(2, "p-early"), PROBE, &mut out);
        let snap = j.snapshot();
        let mut j2 = HashJoin::new(0, 0);
        j2.restore(snap);
        assert!(!j2.build_done);
        assert_eq!(j2.early_probe.len(), 1);
        j2.process(kv(2, "b2"), BUILD, &mut out);
        j2.finish_port(BUILD, &mut out);
        assert_eq!(out.0.len(), 1, "early probe matched post-restore build");
    }

    // ---- out-of-core ----

    fn tiny_ctx(limit: u64) -> SpillCtx {
        let mut cfg = Config::for_tests();
        cfg.memory_budget_bytes = limit;
        SpillCtx::new(&cfg)
    }

    fn run_join(ctx: Option<&SpillCtx>) -> Vec<String> {
        let mut j = HashJoin::new(0, 0);
        if let Some(c) = ctx {
            j.attach_spill(c);
        }
        let mut out = VecEmitter::default();
        for i in 0..200i64 {
            j.process(kv(i % 37, &format!("b{i}")), BUILD, &mut out);
        }
        // A few early probes before build EOF.
        for i in 0..20i64 {
            j.process(kv(i % 37, &format!("e{i}")), PROBE, &mut out);
        }
        j.finish_port(BUILD, &mut out);
        for i in 0..300i64 {
            j.process(kv(i % 41, &format!("p{i}")), PROBE, &mut out);
        }
        j.finish_port(PROBE, &mut out);
        j.finish(&mut out);
        let mut v: Vec<String> = out.0.iter().map(|t| format!("{t:?}")).collect();
        v.sort_unstable();
        v
    }

    #[test]
    fn spilled_join_matches_unbounded() {
        let unbounded = run_join(None);
        let ctx = tiny_ctx(512); // far below resident state size
        let spilled = run_join(Some(&ctx));
        assert_eq!(spilled, unbounded);
        let stats = ctx.counters.snapshot(&ctx.budget);
        assert!(stats.bytes_spilled > 0, "tiny budget must spill");
        assert!(stats.partitions_spilled > 0);
    }

    #[test]
    fn spilled_snapshot_restores_byte_exact() {
        let unbounded = run_join(None);
        let ctx = tiny_ctx(512);
        // Run the build phase spilled, snapshot mid-stream, restore
        // into a fresh operator on the same ctx, then finish there.
        let mut j = HashJoin::new(0, 0);
        j.attach_spill(&ctx);
        let mut out = VecEmitter::default();
        for i in 0..200i64 {
            j.process(kv(i % 37, &format!("b{i}")), BUILD, &mut out);
        }
        for i in 0..20i64 {
            j.process(kv(i % 37, &format!("e{i}")), PROBE, &mut out);
        }
        let snap = j.snapshot();
        assert!(!snap.spill.is_empty(), "manifest carries spilled partitions");
        // Post-snapshot appends must be truncated away by restore.
        j.process(kv(999, "junk"), BUILD, &mut out);
        let mut j2 = HashJoin::new(0, 0);
        j2.attach_spill(&ctx);
        j2.restore(snap);
        let mut out2 = VecEmitter::default();
        j2.finish_port(BUILD, &mut out2);
        for i in 0..300i64 {
            j2.process(kv(i % 41, &format!("p{i}")), PROBE, &mut out2);
        }
        j2.finish_port(PROBE, &mut out2);
        j2.finish(&mut out2);
        let mut got: Vec<String> = out2.0.iter().map(|t| format!("{t:?}")).collect();
        got.sort_unstable();
        assert_eq!(got, unbounded);
    }

    #[test]
    fn spilled_extract_returns_full_table() {
        let ctx = tiny_ctx(256);
        let mut j = HashJoin::new(0, 0);
        j.attach_spill(&ctx);
        let mut out = VecEmitter::default();
        for i in 0..100i64 {
            j.process(kv(i, &format!("b{i}")), BUILD, &mut out);
        }
        assert!(!j.spilled.is_empty(), "must have spilled");
        let st = j.extract_state(None, false);
        let total: usize = st.keyed_tuples.values().map(Vec::len).sum();
        assert_eq!(total, 100, "extraction sees spilled + resident state");
        assert_eq!(j.state_size(), 0);
    }

    #[test]
    fn spilled_probe_input_drains_for_reroute() {
        let ctx = tiny_ctx(256);
        let mut j = HashJoin::new(0, 0);
        j.attach_spill(&ctx);
        let mut out = VecEmitter::default();
        for i in 0..100i64 {
            j.process(kv(i, &format!("b{i}")), BUILD, &mut out);
        }
        for i in 0..30i64 {
            j.process(kv(i, &format!("e{i}")), PROBE, &mut out);
        }
        j.finish_port(BUILD, &mut out);
        for i in 0..30i64 {
            j.process(kv(i, &format!("p{i}")), PROBE, &mut out);
        }
        let drained = j.drain_buffered_input();
        let total: usize = drained.iter().map(|(_, v)| v.len()).sum();
        assert!(total > 0, "spilled probe input must drain");
        assert!(drained.iter().all(|(port, _)| *port == PROBE));
    }
}

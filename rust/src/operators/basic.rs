//! Stateless operators (§2.4.3 category 1): selection, projection,
//! keyword search, regex parsing, UDF map, union.
//!
//! The hot ones (filter, project, keyword search, union) override
//! [`Operator::process_batch`] to amortize dispatch across a chunk and
//! to forward the *shared* batch allocation unchanged whenever every
//! tuple passes — the common case on selective-late pipelines.
//!
//! On columnar batches ([`TupleBatch::columns`]) they go further:
//! predicates read the key column directly (typed slice scans for
//! `Int`-vs-`Int` filters and string-column keyword search — no row
//! materialization), partial passes gather the kept rows
//! column-at-a-time, and projection is O(arity) `Arc` clones of the
//! retained columns. Results are byte-identical to the row path; the
//! `columnar ≡ row` property tests pin that.
//!
//! These support runtime modification via [`Operator::modify`] — the
//! paper's "change the threshold in a selection predicate, a regular
//! expression in an entity extractor operator" (§2.1).

use crate::engine::operator::{Emitter, OpPatch, Operator};
use crate::tuple::{value_cmp, Tuple, TupleBatch, Value};

/// Comparison operator for [`Filter`] predicates.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Cmp {
    Lt,
    Le,
    Eq,
    Ge,
    Gt,
    Ne,
}

impl Cmp {
    fn eval(self, ord: std::cmp::Ordering) -> bool {
        use std::cmp::Ordering::*;
        matches!(
            (self, ord),
            (Cmp::Lt, Less)
                | (Cmp::Le, Less)
                | (Cmp::Le, Equal)
                | (Cmp::Eq, Equal)
                | (Cmp::Ge, Equal)
                | (Cmp::Ge, Greater)
                | (Cmp::Gt, Greater)
                | (Cmp::Ne, Less)
                | (Cmp::Ne, Greater)
        )
    }
}

/// Selection: keep tuples where `field <cmp> constant`. The constant is
/// runtime-modifiable (`modify("constant", v)`), as is the comparison
/// (`modify("cmp", "<"|"<="|"=="|">="|">"|"!=")`).
pub struct Filter {
    pub field: usize,
    pub cmp: Cmp,
    pub constant: Value,
    /// Artificial per-tuple cost in nanoseconds (models expensive
    /// predicates; 0 = none).
    pub cost_ns: u64,
}

impl Filter {
    pub fn new(field: usize, cmp: Cmp, constant: Value) -> Filter {
        Filter { field, cmp, constant, cost_ns: 0 }
    }
}

impl Filter {
    #[inline]
    fn keep(&self, t: &Tuple) -> bool {
        self.cmp.eval(value_cmp(t.get(self.field), &self.constant))
    }
}

impl Operator for Filter {
    fn name(&self) -> &str {
        "filter"
    }

    fn process(&mut self, t: Tuple, _port: usize, out: &mut dyn Emitter) {
        if self.cost_ns > 0 {
            busy_spin(self.cost_ns);
        }
        if self.keep(&t) {
            out.emit(t);
        }
    }

    fn process_batch(&mut self, batch: &TupleBatch, _port: usize, out: &mut dyn Emitter) {
        if self.cost_ns > 0 {
            busy_spin(self.cost_ns * batch.len() as u64);
        }
        // Columnar: typed slice scan for the Int-vs-Int case (the
        // benchmark's hot filter), generic per-value scan otherwise;
        // both select without materializing rows.
        if let Some(cv) = batch.columns() {
            if let Some(col) = cv.set.cols.get(self.field) {
                if let (Some((vals, validity)), Value::Int(c)) =
                    (col.int_vals(), &self.constant)
                {
                    let c = *c;
                    let cmp = self.cmp;
                    // Null sorts below every non-null (`value_cmp`), so
                    // an invalid entry compares as Less.
                    let null_keep = cmp.eval(std::cmp::Ordering::Less);
                    let n = batch.len();
                    let mut sel: Vec<u32> = Vec::with_capacity(n);
                    match validity {
                        None => {
                            for (i, v) in vals[cv.start..cv.end].iter().enumerate() {
                                if cmp.eval(v.cmp(&c)) {
                                    sel.push(i as u32);
                                }
                            }
                        }
                        Some(mask) => {
                            for i in 0..n {
                                let j = cv.start + i;
                                let keep = if mask[j] {
                                    cmp.eval(vals[j].cmp(&c))
                                } else {
                                    null_keep
                                };
                                if keep {
                                    sel.push(i as u32);
                                }
                            }
                        }
                    }
                    emit_selected(batch, &cv, &sel, out);
                    return;
                }
            }
        }
        let cmp = self.cmp;
        let constant = &self.constant;
        if emit_filtered_columnar(batch, self.field, out, |v| {
            cmp.eval(value_cmp(v, constant))
        }) {
            return;
        }
        emit_filtered(batch, out, |t| self.keep(t));
    }

    fn modify(&mut self, patch: &OpPatch) -> Result<(), String> {
        match patch.param.as_str() {
            "constant" => {
                self.constant = parse_value(&patch.value);
                Ok(())
            }
            "cmp" => {
                self.cmp = match patch.value.as_str() {
                    "<" => Cmp::Lt,
                    "<=" => Cmp::Le,
                    "==" => Cmp::Eq,
                    ">=" => Cmp::Ge,
                    ">" => Cmp::Gt,
                    "!=" => Cmp::Ne,
                    other => return Err(format!("bad cmp {other}")),
                };
                Ok(())
            }
            p => Err(format!("filter: unknown parameter {p}")),
        }
    }
}

fn parse_value(s: &str) -> Value {
    if let Ok(i) = s.parse::<i64>() {
        Value::Int(i)
    } else if let Ok(f) = s.parse::<f64>() {
        Value::Float(f)
    } else {
        Value::str(s)
    }
}

fn busy_spin(ns: u64) {
    let t0 = std::time::Instant::now();
    while (t0.elapsed().as_nanos() as u64) < ns {
        std::hint::spin_loop();
    }
}

/// Single-pass batched selection: evaluates `pred` once per tuple,
/// forwards the *shared* allocation when everything passes (zero
/// clones), and otherwise clones only the kept tuples.
fn emit_filtered(
    batch: &TupleBatch,
    out: &mut dyn Emitter,
    mut pred: impl FnMut(&Tuple) -> bool,
) {
    let mut kept: Option<Vec<Tuple>> = None;
    for (i, t) in batch.iter().enumerate() {
        if pred(t) {
            if let Some(v) = kept.as_mut() {
                v.push(t.clone());
            }
        } else if kept.is_none() {
            // First rejection: everything before `i` passed.
            let mut v = Vec::with_capacity(batch.len().saturating_sub(1));
            v.extend_from_slice(&batch.as_slice()[..i]);
            kept = Some(v);
        }
    }
    match kept {
        None => {
            if !batch.is_empty() {
                out.emit_batch(batch.clone());
            }
        }
        Some(v) => {
            if !v.is_empty() {
                out.emit_batch(v.into());
            }
        }
    }
}

/// Forward the rows selected by `sel` (indices relative to the view):
/// everything → the shared allocation untouched; a strict subset →
/// a column-at-a-time gather of the kept rows (no row materialization).
fn emit_selected(
    batch: &TupleBatch,
    cv: &crate::tuple::ColumnsView<'_>,
    sel: &[u32],
    out: &mut dyn Emitter,
) {
    if sel.len() == batch.len() {
        out.emit_batch(batch.clone());
    } else if !sel.is_empty() {
        out.emit_batch(TupleBatch::from_columns(cv.set.gather(cv.start, sel)));
    }
}

/// Columnar selection over one key column: evaluate `pred` per value
/// straight off the column (no row transpose), then
/// [`emit_selected`]. Returns `false` when the batch has no columnar
/// view or lacks the field — caller falls back to the row path.
fn emit_filtered_columnar(
    batch: &TupleBatch,
    field: usize,
    out: &mut dyn Emitter,
    mut pred: impl FnMut(&Value) -> bool,
) -> bool {
    let Some(cv) = batch.columns() else {
        return false;
    };
    let Some(col) = cv.set.cols.get(field) else {
        return false;
    };
    let n = batch.len();
    let mut sel: Vec<u32> = Vec::with_capacity(n);
    for i in 0..n {
        if pred(&col.value_at(cv.start + i)) {
            sel.push(i as u32);
        }
    }
    emit_selected(batch, &cv, &sel, out);
    true
}

/// Keyword search over a string field: keep tuples whose field contains
/// *any* of the keywords. Keywords are runtime-modifiable — the
/// "blunt"-tweets example of Ch. 1 (`modify("keywords", "a,b,c")`).
pub struct KeywordSearch {
    pub field: usize,
    pub keywords: Vec<String>,
}

impl KeywordSearch {
    pub fn new(field: usize, keywords: &[&str]) -> KeywordSearch {
        KeywordSearch {
            field,
            keywords: keywords.iter().map(|s| s.to_string()).collect(),
        }
    }
}

impl KeywordSearch {
    #[inline]
    fn matches(&self, t: &Tuple) -> bool {
        t.get(self.field)
            .as_str()
            .map(|text| self.keywords.iter().any(|k| text.contains(k.as_str())))
            .unwrap_or(false)
    }
}

impl Operator for KeywordSearch {
    fn name(&self) -> &str {
        "keyword_search"
    }

    fn process(&mut self, t: Tuple, _port: usize, out: &mut dyn Emitter) {
        if self.matches(&t) {
            out.emit(t);
        }
    }

    fn process_batch(&mut self, batch: &TupleBatch, _port: usize, out: &mut dyn Emitter) {
        // Columnar: scan the string column directly — `contains` runs
        // against the shared `Arc<str>` payloads, no row or `Value`
        // construction. Null/invalid entries never match, exactly like
        // the row path's `as_str() → None`.
        if let Some(cv) = batch.columns() {
            if let Some(col) = cv.set.cols.get(self.field) {
                if let Some((vals, validity)) = col.str_vals() {
                    let n = batch.len();
                    let mut sel: Vec<u32> = Vec::with_capacity(n);
                    for i in 0..n {
                        let j = cv.start + i;
                        let valid = validity.map(|m| m[j]).unwrap_or(true);
                        if valid
                            && self
                                .keywords
                                .iter()
                                .any(|k| vals[j].contains(k.as_str()))
                        {
                            sel.push(i as u32);
                        }
                    }
                    emit_selected(batch, &cv, &sel, out);
                    return;
                }
            }
        }
        let keywords = &self.keywords;
        if emit_filtered_columnar(batch, self.field, out, |v| {
            v.as_str()
                .map(|text| keywords.iter().any(|k| text.contains(k.as_str())))
                .unwrap_or(false)
        }) {
            return;
        }
        emit_filtered(batch, out, |t| self.matches(t));
    }

    fn modify(&mut self, patch: &OpPatch) -> Result<(), String> {
        match patch.param.as_str() {
            "keywords" => {
                self.keywords =
                    patch.value.split(',').map(|s| s.trim().to_string()).collect();
                Ok(())
            }
            p => Err(format!("keyword_search: unknown parameter {p}")),
        }
    }
}

/// Projection: keep the given field positions, in order.
pub struct Project {
    pub fields: Vec<usize>,
}

impl Project {
    pub fn new(fields: &[usize]) -> Project {
        Project { fields: fields.to_vec() }
    }
}

impl Project {
    #[inline]
    fn apply(&self, t: &Tuple) -> Tuple {
        Tuple::new(self.fields.iter().map(|&i| t.get(i).clone()).collect())
    }
}

impl Operator for Project {
    fn name(&self) -> &str {
        "project"
    }

    fn process(&mut self, t: Tuple, _port: usize, out: &mut dyn Emitter) {
        out.emit(self.apply(&t))
    }

    fn process_batch(&mut self, batch: &TupleBatch, _port: usize, out: &mut dyn Emitter) {
        if batch.is_empty() {
            return;
        }
        // Columnar projection is O(arity): clone the retained column
        // `Arc`s and re-slice the view — no per-tuple work at all.
        if let Some(cv) = batch.columns() {
            if self.fields.iter().all(|&f| f < cv.set.arity()) {
                let projected = TupleBatch::from_columns(cv.set.project(&self.fields));
                out.emit_batch(projected.slice(cv.start, cv.end));
                return;
            }
        }
        out.emit_batch(batch.iter().map(|t| self.apply(t)).collect());
    }
}

/// Regex-style parser: splits a raw text field on a delimiter into
/// typed fields (the RegexParser of §2.5.1). Unparseable tuples are
/// never fatal: they are skipped and counted (`dropped`, plus
/// `strict_skipped` with a sample of the offending input when
/// `strict`). An earlier revision panicked in strict mode, which
/// killed the whole worker thread on one bad row — the exact failure
/// the Fig. 1.1 adaptivity story exists to avoid; now the workflow
/// keeps running and the counters surface the problem for a breakpoint
/// or a runtime `modify` to act on.
pub struct RegexParser {
    pub field: usize,
    pub delimiter: char,
    pub expected_fields: usize,
    pub strict: bool,
    /// Count of skipped (unparseable) tuples.
    pub dropped: u64,
    /// Skipped tuples observed while `strict` — the "should have been
    /// an error" count.
    pub strict_skipped: u64,
    /// Sample of the most recent strict-mode offender (diagnostics).
    pub last_bad_input: Option<String>,
}

impl RegexParser {
    pub fn new(field: usize, delimiter: char, expected_fields: usize) -> RegexParser {
        RegexParser {
            field,
            delimiter,
            expected_fields,
            strict: false,
            dropped: 0,
            strict_skipped: 0,
            last_bad_input: None,
        }
    }

    fn skip(&mut self, raw: Option<&str>) {
        self.dropped += 1;
        if self.strict {
            self.strict_skipped += 1;
            if let Some(r) = raw {
                self.last_bad_input = Some(r.to_string());
            }
        }
    }
}

impl Operator for RegexParser {
    fn name(&self) -> &str {
        "regex_parser"
    }

    fn process(&mut self, t: Tuple, _port: usize, out: &mut dyn Emitter) {
        let Some(raw) = t.get(self.field).as_str() else {
            self.skip(None);
            return;
        };
        let parts: Vec<&str> = raw.split(self.delimiter).collect();
        if parts.len() != self.expected_fields {
            let raw = raw.to_string();
            self.skip(Some(&raw));
            return;
        }
        out.emit(Tuple::new(parts.iter().map(|p| parse_value(p)).collect()));
    }

    fn modify(&mut self, patch: &OpPatch) -> Result<(), String> {
        match patch.param.as_str() {
            // The Ch. 1 adaptivity scenario: switch the parser to a
            // lenient mode at runtime instead of crashing the workflow.
            "strict" => {
                self.strict = patch.value == "true";
                Ok(())
            }
            "delimiter" => {
                self.delimiter =
                    patch.value.chars().next().ok_or("empty delimiter")?;
                Ok(())
            }
            p => Err(format!("regex_parser: unknown parameter {p}")),
        }
    }
}

/// A user-defined map with an artificial per-tuple cost — stands in for
/// expensive UDFs when the real PJRT-backed ML operator is overkill
/// (e.g. the Fig. 2.12 worker-count sweep). The cost is a *sleep*, not
/// a spin: the paper's SentimentAnalysis (~4 s/tuple CognitiveRocket)
/// is latency-bound, which is why adding workers helps — a property
/// that survives our single-core testbed.
pub struct MapUdf {
    pub f: Box<dyn FnMut(&Tuple) -> Tuple + Send>,
    pub cost_ns: u64,
}

impl MapUdf {
    pub fn identity(cost_ns: u64) -> MapUdf {
        MapUdf { f: Box::new(|t| t.clone()), cost_ns }
    }
}

impl Operator for MapUdf {
    fn name(&self) -> &str {
        "map_udf"
    }

    fn process(&mut self, t: Tuple, _port: usize, out: &mut dyn Emitter) {
        if self.cost_ns > 0 {
            std::thread::sleep(std::time::Duration::from_nanos(self.cost_ns));
        }
        out.emit((self.f)(&t));
    }
}

/// Union: forward tuples from all input ports unchanged.
pub struct Union {
    ports: usize,
}

impl Union {
    pub fn new(ports: usize) -> Union {
        Union { ports }
    }
}

impl Operator for Union {
    fn name(&self) -> &str {
        "union"
    }

    fn num_ports(&self) -> usize {
        self.ports
    }

    fn process(&mut self, t: Tuple, _port: usize, out: &mut dyn Emitter) {
        out.emit(t);
    }

    fn process_batch(&mut self, batch: &TupleBatch, _port: usize, out: &mut dyn Emitter) {
        out.emit_batch(batch.clone());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::operator::VecEmitter;

    fn t(vals: Vec<Value>) -> Tuple {
        Tuple::new(vals)
    }

    #[test]
    fn filter_keeps_matching() {
        let mut f = Filter::new(0, Cmp::Lt, Value::Int(5));
        let mut out = VecEmitter::default();
        for i in 0..10 {
            f.process(t(vec![Value::Int(i)]), 0, &mut out);
        }
        assert_eq!(out.0.len(), 5);
    }

    #[test]
    fn filter_modify_constant_at_runtime() {
        let mut f = Filter::new(0, Cmp::Lt, Value::Int(5));
        f.modify(&OpPatch { param: "constant".into(), value: "8".into() })
            .unwrap();
        let mut out = VecEmitter::default();
        for i in 0..10 {
            f.process(t(vec![Value::Int(i)]), 0, &mut out);
        }
        assert_eq!(out.0.len(), 8);
    }

    #[test]
    fn filter_modify_cmp() {
        let mut f = Filter::new(0, Cmp::Lt, Value::Int(5));
        f.modify(&OpPatch { param: "cmp".into(), value: ">=".into() })
            .unwrap();
        let mut out = VecEmitter::default();
        for i in 0..10 {
            f.process(t(vec![Value::Int(i)]), 0, &mut out);
        }
        assert_eq!(out.0.len(), 5);
    }

    #[test]
    fn filter_rejects_unknown_param() {
        let mut f = Filter::new(0, Cmp::Lt, Value::Int(5));
        assert!(f
            .modify(&OpPatch { param: "nope".into(), value: "1".into() })
            .is_err());
    }

    #[test]
    fn filter_batch_matches_per_tuple() {
        let batch: TupleBatch =
            (0..10).map(|i| t(vec![Value::Int(i)])).collect();
        let mut a = Filter::new(0, Cmp::Lt, Value::Int(5));
        let mut out_b = VecEmitter::default();
        a.process_batch(&batch, 0, &mut out_b);
        let mut out_t = VecEmitter::default();
        for tup in batch.iter() {
            a.process(tup.clone(), 0, &mut out_t);
        }
        assert_eq!(out_b.0, out_t.0);
        assert_eq!(out_b.0.len(), 5);
    }

    #[test]
    fn filter_all_pass_forwards_shared_batch() {
        struct Capture(Option<TupleBatch>);
        impl Emitter for Capture {
            fn emit(&mut self, _t: Tuple) {
                panic!("expected a batch emit");
            }
            fn emit_batch(&mut self, b: TupleBatch) {
                self.0 = Some(b);
            }
        }
        let batch: TupleBatch =
            (0..6).map(|i| t(vec![Value::Int(i)])).collect();
        let mut f = Filter::new(0, Cmp::Ge, Value::Int(0));
        let mut cap = Capture(None);
        f.process_batch(&batch, 0, &mut cap);
        let got = cap.0.expect("no batch emitted");
        assert!(
            TupleBatch::ptr_eq(&batch, &got),
            "all-pass filter must forward the shared allocation"
        );
    }

    #[test]
    fn project_batch_matches_per_tuple() {
        let batch: TupleBatch = (0..4)
            .map(|i| t(vec![Value::Int(i), Value::str("x")]))
            .collect();
        let mut p = Project::new(&[1, 0]);
        let mut out_b = VecEmitter::default();
        p.process_batch(&batch, 0, &mut out_b);
        assert_eq!(out_b.0.len(), 4);
        assert_eq!(out_b.0[2].get(1).as_int(), Some(2));
    }

    fn columnar(rows: Vec<Tuple>) -> TupleBatch {
        TupleBatch::from_columns(
            crate::column::ColumnSet::from_rows(&rows).expect("uniform rows"),
        )
    }

    #[test]
    fn filter_columnar_matches_row_path() {
        let rows: Vec<Tuple> = (0..10)
            .map(|i| {
                t(vec![
                    if i == 3 { Value::Null } else { Value::Int(i) },
                    Value::str("x"),
                ])
            })
            .collect();
        let row_batch = TupleBatch::new(rows.clone());
        let col_batch = columnar(rows);
        for cmp in [Cmp::Lt, Cmp::Le, Cmp::Eq, Cmp::Ge, Cmp::Gt, Cmp::Ne] {
            let mut f = Filter::new(0, cmp, Value::Int(5));
            let mut out_r = VecEmitter::default();
            f.process_batch(&row_batch, 0, &mut out_r);
            let mut out_c = VecEmitter::default();
            f.process_batch(&col_batch, 0, &mut out_c);
            assert_eq!(out_r.0, out_c.0, "cmp {cmp:?} diverged");
        }
    }

    #[test]
    fn filter_columnar_all_pass_forwards_shared_batch() {
        let col_batch =
            columnar((0..6).map(|i| t(vec![Value::Int(i)])).collect());
        struct Capture(Option<TupleBatch>);
        impl Emitter for Capture {
            fn emit(&mut self, _t: Tuple) {
                panic!("expected a batch emit");
            }
            fn emit_batch(&mut self, b: TupleBatch) {
                self.0 = Some(b);
            }
        }
        let mut f = Filter::new(0, Cmp::Ge, Value::Int(0));
        let mut cap = Capture(None);
        f.process_batch(&col_batch, 0, &mut cap);
        let got = cap.0.expect("no batch emitted");
        assert!(
            TupleBatch::ptr_eq(&col_batch, &got),
            "all-pass columnar filter must forward the shared allocation"
        );
    }

    #[test]
    fn keyword_columnar_matches_row_path() {
        let rows = vec![
            t(vec![Value::str("covid cases rise")]),
            t(vec![Value::str("sunny day")]),
            t(vec![Value::Null]),
            t(vec![Value::str("flu season")]),
        ];
        let row_batch = TupleBatch::new(rows.clone());
        let col_batch = columnar(rows);
        let mut k = KeywordSearch::new(0, &["covid", "flu"]);
        let mut out_r = VecEmitter::default();
        k.process_batch(&row_batch, 0, &mut out_r);
        let mut out_c = VecEmitter::default();
        k.process_batch(&col_batch, 0, &mut out_c);
        assert_eq!(out_r.0, out_c.0);
        assert_eq!(out_r.0.len(), 2);
    }

    #[test]
    fn project_columnar_matches_row_path_on_sliced_view() {
        let rows: Vec<Tuple> = (0..8)
            .map(|i| t(vec![Value::Int(i), Value::str("x"), Value::Float(i as f64)]))
            .collect();
        let row_batch = TupleBatch::new(rows.clone()).slice(2, 7);
        let col_batch = columnar(rows).slice(2, 7);
        let mut p = Project::new(&[2, 0]);
        let mut out_r = VecEmitter::default();
        p.process_batch(&row_batch, 0, &mut out_r);
        let mut out_c = VecEmitter::default();
        p.process_batch(&col_batch, 0, &mut out_c);
        assert_eq!(out_r.0, out_c.0);
        assert_eq!(out_r.0.len(), 5);
        assert_eq!(out_r.0[0].get(1).as_int(), Some(2));
    }

    #[test]
    fn keyword_search_any_match() {
        let mut k = KeywordSearch::new(0, &["covid", "flu"]);
        let mut out = VecEmitter::default();
        k.process(t(vec![Value::str("covid cases rise")]), 0, &mut out);
        k.process(t(vec![Value::str("sunny day")]), 0, &mut out);
        k.process(t(vec![Value::str("flu season")]), 0, &mut out);
        assert_eq!(out.0.len(), 2);
    }

    #[test]
    fn keyword_modify_fixes_blunt_problem() {
        // Ch. 1: "blunt" collects Emily Blunt tweets; narrow at runtime.
        let mut k = KeywordSearch::new(0, &["blunt"]);
        let mut out = VecEmitter::default();
        k.process(t(vec![Value::str("emily blunt movie")]), 0, &mut out);
        assert_eq!(out.0.len(), 1);
        k.modify(&OpPatch {
            param: "keywords".into(),
            value: "blunt smoking,blunt wrap".into(),
        })
        .unwrap();
        k.process(t(vec![Value::str("emily blunt movie")]), 0, &mut out);
        assert_eq!(out.0.len(), 1); // no longer matches
    }

    #[test]
    fn project_reorders() {
        let mut p = Project::new(&[1, 0]);
        let mut out = VecEmitter::default();
        p.process(t(vec![Value::Int(1), Value::str("x")]), 0, &mut out);
        assert_eq!(out.0[0].get(0).as_str(), Some("x"));
        assert_eq!(out.0[0].get(1).as_int(), Some(1));
    }

    #[test]
    fn parser_splits_and_types() {
        let mut p = RegexParser::new(0, '\t', 3);
        let mut out = VecEmitter::default();
        p.process(t(vec![Value::str("7\thello\t2.5")]), 0, &mut out);
        assert_eq!(out.0[0].get(0).as_int(), Some(7));
        assert_eq!(out.0[0].get(1).as_str(), Some("hello"));
        assert_eq!(out.0[0].get(2).as_float(), Some(2.5));
    }

    #[test]
    fn parser_drops_bad_rows_when_lenient() {
        let mut p = RegexParser::new(0, '\t', 3);
        let mut out = VecEmitter::default();
        p.process(t(vec![Value::str("only\ttwo")]), 0, &mut out);
        assert_eq!(out.0.len(), 0);
        assert_eq!(p.dropped, 1);
    }

    #[test]
    fn parser_strict_skips_and_counts_instead_of_crashing() {
        // Malformed rows must never kill the worker (Fig. 1.1): strict
        // mode records the skip and a sample of the offender instead.
        let mut p = RegexParser::new(0, '\t', 3);
        p.strict = true;
        let mut out = VecEmitter::default();
        p.process(t(vec![Value::str("bad")]), 0, &mut out);
        p.process(t(vec![Value::str("also\tbad")]), 0, &mut out);
        // A non-string field is also skipped, not fatal.
        p.process(t(vec![Value::Int(7)]), 0, &mut out);
        // Well-formed rows still parse after the bad ones.
        p.process(t(vec![Value::str("1\ttwo\t3.0")]), 0, &mut out);
        assert_eq!(out.0.len(), 1);
        assert_eq!(p.dropped, 3);
        assert_eq!(p.strict_skipped, 3);
        assert_eq!(p.last_bad_input.as_deref(), Some("also\tbad"));
    }

    #[test]
    fn union_forwards_all_ports() {
        let mut u = Union::new(2);
        let mut out = VecEmitter::default();
        u.process(t(vec![Value::Int(1)]), 0, &mut out);
        u.process(t(vec![Value::Int(2)]), 1, &mut out);
        assert_eq!(out.0.len(), 2);
        assert_eq!(u.num_ports(), 2);
    }
}

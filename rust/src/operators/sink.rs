//! Sink / result operators (Def. 4.1): collect results for the driver
//! and maintain live visualization-style aggregates.
//!
//! [`CountByKeySink`] is the "bar chart" of the running example: the
//! experiment harness polls its per-key counters to plot the observed
//! CA:AZ ratio over time (Figs. 3.16–3.19) with negligible overhead
//! (atomic adds).

use crate::engine::operator::{Emitter, OpState, Operator};
use crate::tuple::{Tuple, TupleBatch};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

/// Shared handle the driver keeps to read sink contents during/after a
/// run.
#[derive(Clone, Default)]
pub struct SinkHandle {
    /// Raw captured tuples (only if capture enabled).
    captured: Arc<Mutex<Vec<Tuple>>>,
    /// Count per small-integer key (bar-chart counters).
    counts: Arc<Vec<AtomicU64>>,
    /// Total tuples seen.
    total: Arc<AtomicU64>,
    /// Total bytes seen (materialization-size accounting).
    bytes: Arc<AtomicU64>,
}

impl SinkHandle {
    /// Handle with `n_keys` bar-chart counters.
    pub fn new(n_keys: usize) -> SinkHandle {
        SinkHandle {
            captured: Arc::new(Mutex::new(Vec::new())),
            counts: Arc::new((0..n_keys).map(|_| AtomicU64::new(0)).collect()),
            total: Arc::new(AtomicU64::new(0)),
            bytes: Arc::new(AtomicU64::new(0)),
        }
    }

    pub fn total(&self) -> u64 {
        self.total.load(Ordering::Relaxed)
    }

    pub fn bytes(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }

    /// Bar-chart reading for one key.
    pub fn count_of(&self, key: usize) -> u64 {
        self.counts
            .get(key)
            .map(|c| c.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    /// Observed ratio of two keys' counts (the Fig. 3.16 monitor);
    /// NaN until both are nonzero.
    pub fn ratio(&self, a: usize, b: usize) -> f64 {
        let ca = self.count_of(a) as f64;
        let cb = self.count_of(b) as f64;
        if cb == 0.0 {
            f64::NAN
        } else {
            ca / cb
        }
    }

    /// Captured tuples (clone).
    pub fn tuples(&self) -> Vec<Tuple> {
        self.captured_lock().clone()
    }

    /// The captured-tuples lock, recovering from poisoning: a sink
    /// worker that panicked mid-push must not cascade-panic the driver
    /// or its recovered replacement (the contents stay well-formed —
    /// pushes append whole tuples).
    fn captured_lock(&self) -> MutexGuard<'_, Vec<Tuple>> {
        self.captured.lock().unwrap_or_else(|e| e.into_inner())
    }
}

/// Sink that captures every tuple (small result sets: sorted outputs,
/// aggregates).
pub struct CollectSink {
    pub handle: SinkHandle,
}

impl CollectSink {
    pub fn new(handle: SinkHandle) -> CollectSink {
        CollectSink { handle }
    }
}

impl Operator for CollectSink {
    fn name(&self) -> &str {
        "collect_sink"
    }

    fn process(&mut self, t: Tuple, _port: usize, out: &mut dyn Emitter) {
        self.handle.total.fetch_add(1, Ordering::Relaxed);
        self.handle
            .bytes
            .fetch_add(t.byte_size() as u64, Ordering::Relaxed);
        self.handle.captured_lock().push(t.clone());
        // Report the delivered result as this worker's output: sinks
        // have no out-edges, so nothing is routed, but the `produced`
        // gauge and the first-output timestamp (Maestro's measured
        // first-response time, §4.5.3) now mark *result delivery*
        // rather than input arrival.
        out.emit(t);
    }

    /// Batched capture: two atomic adds and one lock per chunk instead
    /// of per tuple.
    fn process_batch(&mut self, batch: &TupleBatch, _port: usize, out: &mut dyn Emitter) {
        if batch.is_empty() {
            return;
        }
        self.handle
            .total
            .fetch_add(batch.len() as u64, Ordering::Relaxed);
        self.handle
            .bytes
            .fetch_add(batch.byte_size() as u64, Ordering::Relaxed);
        self.handle
            .captured_lock()
            .extend_from_slice(batch.as_slice());
        // Delivered-results accounting (see `process`): an Arc clone of
        // the shared batch, dropped by the edge-less emitter.
        out.emit_batch(batch.clone());
    }

    /// Checkpoint the *externally visible* sink contents. A quiesced
    /// checkpoint captures the shared [`SinkHandle`] exactly as the
    /// driver could observe it; [`Operator::restore`] puts it back, so
    /// in-place supervised recovery rolls back post-checkpoint
    /// deliveries instead of duplicating them. (With a fresh handle —
    /// the external [`crate::engine::Execution::recover`] path — the
    /// restore re-populates the pre-checkpoint deliveries.)
    fn snapshot(&self) -> OpState {
        let mut s = OpState::default();
        s.keyed_tuples.insert(0, self.handle.tuples());
        s.counters
            .insert("total".into(), self.handle.total() as i64);
        s.counters
            .insert("bytes".into(), self.handle.bytes() as i64);
        s
    }

    fn state_size(&self) -> usize {
        self.handle.total() as usize
    }

    /// Reset the shared handle to the checkpointed contents. With
    /// several sink workers sharing one handle each snapshot holds the
    /// same quiesced contents, so repeated restores are idempotent.
    fn restore(&mut self, mut s: OpState) {
        let rows = s.keyed_tuples.remove(&0).unwrap_or_default();
        *self.handle.captured_lock() = rows;
        let total = s.counters.get("total").copied().unwrap_or(0).max(0) as u64;
        let bytes = s.counters.get("bytes").copied().unwrap_or(0).max(0) as u64;
        self.handle.total.store(total, Ordering::Relaxed);
        self.handle.bytes.store(bytes, Ordering::Relaxed);
    }
}

/// Sink that only counts per key (big result streams: the bar-chart
/// visualization). `key_field` must hold small non-negative ints.
pub struct CountByKeySink {
    pub handle: SinkHandle,
    pub key_field: usize,
}

impl CountByKeySink {
    pub fn new(handle: SinkHandle, key_field: usize) -> CountByKeySink {
        CountByKeySink { handle, key_field }
    }

    #[inline]
    fn count_key(&self, t: &Tuple) {
        if let Some(k) = t.get(self.key_field).as_int() {
            if k >= 0 {
                if let Some(c) = self.handle.counts.get(k as usize) {
                    c.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
    }

    /// Columnar count: run the typed `i64` slice into a local
    /// histogram, then publish one atomic add per touched key instead
    /// of one per tuple. Returns `false` when the key column isn't a
    /// typed Int vector (caller falls back to the row loop).
    fn count_keys_columnar(&self, batch: &TupleBatch) -> bool {
        let Some(cv) = batch.columns() else { return false };
        let Some(col) = cv.set.cols.get(self.key_field) else { return false };
        let Some((vals, validity)) = col.int_vals() else { return false };
        let n_keys = self.handle.counts.len();
        let mut local = vec![0u64; n_keys];
        match validity {
            None => {
                for &k in &vals[cv.start..cv.end] {
                    if k >= 0 && (k as usize) < n_keys {
                        local[k as usize] += 1;
                    }
                }
            }
            Some(m) => {
                for (i, &k) in vals[cv.start..cv.end].iter().enumerate() {
                    if m[cv.start + i] && k >= 0 && (k as usize) < n_keys {
                        local[k as usize] += 1;
                    }
                }
            }
        }
        for (c, &n) in self.handle.counts.iter().zip(local.iter()) {
            if n > 0 {
                c.fetch_add(n, Ordering::Relaxed);
            }
        }
        true
    }
}

impl Operator for CountByKeySink {
    fn name(&self) -> &str {
        "count_by_key_sink"
    }

    fn process(&mut self, t: Tuple, _port: usize, out: &mut dyn Emitter) {
        self.handle.total.fetch_add(1, Ordering::Relaxed);
        self.handle
            .bytes
            .fetch_add(t.byte_size() as u64, Ordering::Relaxed);
        self.count_key(&t);
        // Delivered-results accounting (see `CollectSink::process`).
        out.emit(t);
    }

    fn process_batch(&mut self, batch: &TupleBatch, _port: usize, out: &mut dyn Emitter) {
        if batch.is_empty() {
            return;
        }
        self.handle
            .total
            .fetch_add(batch.len() as u64, Ordering::Relaxed);
        self.handle
            .bytes
            .fetch_add(batch.byte_size() as u64, Ordering::Relaxed);
        if !self.count_keys_columnar(batch) {
            for t in batch.iter() {
                self.count_key(t);
            }
        }
        out.emit_batch(batch.clone());
    }

    /// Checkpoint the externally visible bar-chart counters (see
    /// [`CollectSink::snapshot`] for the rollback rationale).
    fn snapshot(&self) -> OpState {
        let mut s = OpState::default();
        for (k, c) in self.handle.counts.iter().enumerate() {
            s.keyed_aggs
                .insert(k as u64, vec![c.load(Ordering::Relaxed) as f64]);
        }
        s.counters
            .insert("total".into(), self.handle.total() as i64);
        s.counters
            .insert("bytes".into(), self.handle.bytes() as i64);
        s
    }

    fn restore(&mut self, s: OpState) {
        for (k, c) in self.handle.counts.iter().enumerate() {
            let v = s
                .keyed_aggs
                .get(&(k as u64))
                .and_then(|a| a.first().copied())
                .unwrap_or(0.0);
            c.store(v.max(0.0) as u64, Ordering::Relaxed);
        }
        let total = s.counters.get("total").copied().unwrap_or(0).max(0) as u64;
        let bytes = s.counters.get("bytes").copied().unwrap_or(0).max(0) as u64;
        self.handle.total.store(total, Ordering::Relaxed);
        self.handle.bytes.store(bytes, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::operator::VecEmitter;
    use crate::tuple::Value;

    #[test]
    fn collect_sink_captures() {
        let h = SinkHandle::new(0);
        let mut s = CollectSink::new(h.clone());
        let mut out = VecEmitter::default();
        s.process(Tuple::new(vec![Value::Int(1)]), 0, &mut out);
        assert_eq!(h.total(), 1);
        assert_eq!(h.tuples().len(), 1);
        assert!(h.bytes() > 0);
    }

    #[test]
    fn count_sink_ratio() {
        let h = SinkHandle::new(10);
        let mut s = CountByKeySink::new(h.clone(), 0);
        let mut out = VecEmitter::default();
        for _ in 0..6 {
            s.process(Tuple::new(vec![Value::Int(2)]), 0, &mut out);
        }
        for _ in 0..3 {
            s.process(Tuple::new(vec![Value::Int(5)]), 0, &mut out);
        }
        assert_eq!(h.count_of(2), 6);
        assert!((h.ratio(2, 5) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn columnar_counts_match_row_path() {
        let rows: Vec<Tuple> = (0..40)
            .map(|i| {
                let v = if i % 13 == 0 { Value::Null } else { Value::Int(i % 5) };
                Tuple::new(vec![v, Value::Int(i)])
            })
            .collect();
        let batch = TupleBatch::from_columns(
            crate::column::ColumnSet::from_rows(&rows).expect("uniform rows"),
        );
        let row_h = SinkHandle::new(5);
        let mut row_s = CountByKeySink::new(row_h.clone(), 0);
        let mut out = VecEmitter::default();
        for r in &rows {
            row_s.process(r.clone(), 0, &mut out);
        }
        let col_h = SinkHandle::new(5);
        let mut col_s = CountByKeySink::new(col_h.clone(), 0);
        col_s.process_batch(&batch, 0, &mut out);
        assert_eq!(row_h.total(), col_h.total());
        assert_eq!(row_h.bytes(), col_h.bytes());
        for k in 0..5 {
            assert_eq!(row_h.count_of(k), col_h.count_of(k), "key {k}");
        }
    }

    #[test]
    fn ratio_nan_before_data() {
        let h = SinkHandle::new(4);
        assert!(h.ratio(0, 1).is_nan());
    }

    #[test]
    fn out_of_range_key_ignored() {
        let h = SinkHandle::new(2);
        let mut s = CountByKeySink::new(h.clone(), 0);
        let mut out = VecEmitter::default();
        s.process(Tuple::new(vec![Value::Int(99)]), 0, &mut out);
        s.process(Tuple::new(vec![Value::Int(-1)]), 0, &mut out);
        assert_eq!(h.total(), 2);
        assert_eq!(h.count_of(0) + h.count_of(1), 0);
    }
}

//! Distributed sort (§2.4.3 category 2): a range-partitioned first
//! layer sorts local runs; a single-worker second layer merges them.
//!
//! Sort is the paper's canonical *mutable-state* operator for Reshape
//! (§3.5.4): SBR splits a range across the skewed worker and a helper,
//! producing a **scattered state** — the helper accumulates a separate
//! sorted run for the foreign range and ships it back to the range's
//! owner when the input ends (the END-marker merge of Fig. 3.11). Both
//! conditions for scattered-state resolution hold: runs merge by
//! merging sorted lists, and sort blocks until EOF anyway.

use crate::engine::operator::{Emitter, OpState, Operator};
use crate::tuple::{value_cmp, Tuple, TupleBatch};
use std::collections::HashMap;

/// First-layer sorter: accumulates tuples, sorts at EOF, emits the run.
///
/// `scope_of` assigns each tuple a *scope id* (its range index under
/// the plan's range partitioning). Tuples whose scope is not
/// `own_scope` are foreign (the scattered part created by SBR
/// mitigation) and are kept in separate per-scope runs.
pub struct SortWorker {
    pub key_field: usize,
    /// This worker's own range index.
    pub own_scope: u64,
    /// Range upper bounds (same as the partitioner's) for scope
    /// computation; scope = first bound ≥ value.
    pub bounds: Vec<crate::tuple::Value>,
    /// Artificial per-tuple insertion cost in ns (models the paper's
    /// heavier sort workers; 0 = none).
    pub cost_ns: u64,
    runs: HashMap<u64, Vec<Tuple>>,
}

impl SortWorker {
    pub fn new(key_field: usize, own_scope: u64, bounds: Vec<crate::tuple::Value>) -> SortWorker {
        SortWorker { key_field, own_scope, bounds, cost_ns: 0, runs: HashMap::new() }
    }

    /// Builder: artificial per-tuple cost.
    pub fn with_cost(mut self, ns: u64) -> SortWorker {
        self.cost_ns = ns;
        self
    }

    fn scope_of(&self, t: &Tuple) -> u64 {
        let v = t.get(self.key_field);
        for (i, b) in self.bounds.iter().enumerate() {
            if value_cmp(v, b) != std::cmp::Ordering::Greater {
                return i as u64;
            }
        }
        self.bounds.len() as u64
    }

    /// Tuples held for foreign scopes (scattered state size).
    pub fn scattered_tuples(&self) -> usize {
        self.runs
            .iter()
            .filter(|(s, _)| **s != self.own_scope)
            .map(|(_, v)| v.len())
            .sum()
    }
}

impl Operator for SortWorker {
    fn name(&self) -> &str {
        "sort_worker"
    }

    fn blocking_ports(&self) -> Vec<usize> {
        vec![0]
    }

    fn process(&mut self, t: Tuple, _port: usize, _out: &mut dyn Emitter) {
        if self.cost_ns > 0 {
            let t0 = std::time::Instant::now();
            while (t0.elapsed().as_nanos() as u64) < self.cost_ns {
                std::hint::spin_loop();
            }
        }
        let scope = self.scope_of(&t);
        self.runs.entry(scope).or_default().push(t);
    }

    /// Batch absorb: one combined spin (chunk length × per-tuple cost)
    /// and one dispatch per chunk. Sort state stays row-major
    /// (`Vec<Tuple>` runs feed a comparison sort at EOF), so the batch
    /// win here is amortized dispatch and a single cost spin — the
    /// typed-column kernels don't apply.
    fn process_batch(&mut self, batch: &TupleBatch, _port: usize, _out: &mut dyn Emitter) {
        if self.cost_ns > 0 && !batch.is_empty() {
            let total = self.cost_ns * batch.len() as u64;
            let t0 = std::time::Instant::now();
            while (t0.elapsed().as_nanos() as u64) < total {
                std::hint::spin_loop();
            }
        }
        for t in batch.iter() {
            let scope = self.scope_of(t);
            self.runs.entry(scope).or_default().push(t.clone());
        }
    }

    fn finish(&mut self, out: &mut dyn Emitter) {
        // At EOF, only the own-scope run should remain (the engine's
        // Reshape layer migrates foreign runs back to their owners
        // before EOF cascades); any still-foreign tuples are emitted
        // too so no data is lost even without mitigation.
        let mut scopes: Vec<u64> = self.runs.keys().copied().collect();
        scopes.sort_unstable();
        let mut all: Vec<Tuple> = Vec::new();
        for s in scopes {
            all.append(self.runs.get_mut(&s).unwrap());
        }
        all.sort_by(|a, b| value_cmp(a.get(self.key_field), b.get(self.key_field)));
        for t in all {
            out.emit(t);
        }
    }

    fn snapshot(&self) -> OpState {
        let mut s = OpState::default();
        s.keyed_tuples = self.runs.clone();
        s
    }

    fn restore(&mut self, s: OpState) {
        self.runs = s.keyed_tuples;
    }

    fn state_size(&self) -> usize {
        self.runs.values().map(Vec::len).sum()
    }

    fn extract_state(&mut self, keys: Option<&[u64]>, replicate: bool) -> OpState {
        // keys here are *scope ids* (range indexes), not value hashes.
        let mut out = OpState::default();
        let targets: Vec<u64> = match keys {
            None => self.runs.keys().copied().collect(),
            Some(ks) => ks.to_vec(),
        };
        for k in targets {
            let item = if replicate {
                self.runs.get(&k).cloned()
            } else {
                self.runs.remove(&k)
            };
            if let Some(v) = item {
                out.keyed_tuples.insert(k, v);
            }
        }
        out
    }

    fn merge_state(&mut self, s: OpState) {
        for (k, mut v) in s.keyed_tuples {
            self.runs.entry(k).or_default().append(&mut v);
        }
    }

    fn state_mutable(&self) -> bool {
        true
    }

    /// Elastic scaling: adopt the new placement and re-derive the range
    /// bounds with the same interpolation the coordinator applies to
    /// the upstream `Range` partitioner
    /// ([`rescale_bounds`](crate::engine::scale::rescale_bounds)), so
    /// future tuples keep classifying own-vs-foreign consistently with
    /// where the exchange actually sends them. Runs accumulated under
    /// old scope ids stay keyed as they are — the foreign-run fallback
    /// in `finish`/`scattered_parts` emits or ships them
    /// regardless, so the output multiset is unaffected either way;
    /// this hook only prevents a resized worker set from classifying
    /// *all* new input as foreign and funneling it back through the
    /// old workers at EOF.
    fn rescale(&mut self, idx: usize, workers: usize) {
        self.own_scope = idx as u64;
        self.bounds = crate::engine::scale::rescale_bounds(&self.bounds, workers);
    }

    fn scattered_parts(&mut self) -> Vec<(u64, OpState)> {
        // Foreign runs (scopes ≠ own) are shipped back to their owners
        // at EOF (Fig. 3.11(e,f)); scope id == owner worker index
        // under range partitioning.
        let foreign: Vec<u64> = self
            .runs
            .keys()
            .copied()
            .filter(|s| *s != self.own_scope)
            .collect();
        foreign
            .into_iter()
            .map(|scope| {
                let mut st = OpState::default();
                st.keyed_tuples
                    .insert(scope, self.runs.remove(&scope).unwrap());
                (scope, st)
            })
            .collect()
    }
}

/// Second-layer merger: single worker; collects sorted runs from all
/// first-layer workers and merges them at EOF. Input arrives
/// interleaved, so it re-sorts (equivalent to a k-way merge; runs are
/// concatenated then sorted with a stable O(n log n) sort — adequate at
/// our scale and deterministic).
pub struct SortMerge {
    pub key_field: usize,
    buffer: Vec<Tuple>,
}

impl SortMerge {
    pub fn new(key_field: usize) -> SortMerge {
        SortMerge { key_field, buffer: Vec::new() }
    }
}

impl Operator for SortMerge {
    fn name(&self) -> &str {
        "sort_merge"
    }

    fn blocking_ports(&self) -> Vec<usize> {
        vec![0]
    }

    fn process(&mut self, t: Tuple, _port: usize, _out: &mut dyn Emitter) {
        self.buffer.push(t);
    }

    /// Bulk absorb: extend the merge buffer in one call instead of one
    /// virtual dispatch per tuple.
    fn process_batch(&mut self, batch: &TupleBatch, _port: usize, _out: &mut dyn Emitter) {
        self.buffer.extend(batch.iter().cloned());
    }

    fn finish(&mut self, out: &mut dyn Emitter) {
        self.buffer
            .sort_by(|a, b| value_cmp(a.get(self.key_field), b.get(self.key_field)));
        for t in self.buffer.drain(..) {
            out.emit(t);
        }
    }

    fn snapshot(&self) -> OpState {
        let mut s = OpState::default();
        s.keyed_tuples.insert(0, self.buffer.clone());
        s
    }

    fn restore(&mut self, mut s: OpState) {
        self.buffer = s.keyed_tuples.remove(&0).unwrap_or_default();
    }

    fn state_size(&self) -> usize {
        self.buffer.len()
    }

    /// Elastic scaling migrates the merge buffer whole (scope 0): the
    /// merge layer re-sorts everything at EOF, so which worker holds
    /// which run never affects the output order.
    fn extract_state(&mut self, _keys: Option<&[u64]>, replicate: bool) -> OpState {
        let mut s = OpState::default();
        let buf = if replicate {
            self.buffer.clone()
        } else {
            std::mem::take(&mut self.buffer)
        };
        if !buf.is_empty() {
            s.keyed_tuples.insert(0, buf);
        }
        s
    }

    fn merge_state(&mut self, mut s: OpState) {
        for (_, mut v) in s.keyed_tuples.drain() {
            self.buffer.append(&mut v);
        }
    }

    fn state_mutable(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::operator::VecEmitter;
    use crate::tuple::Value;

    fn t1(v: f64) -> Tuple {
        Tuple::new(vec![Value::Float(v)])
    }

    fn bounds() -> Vec<Value> {
        vec![Value::Float(10.0), Value::Float(20.0)]
    }

    #[test]
    fn sorts_own_range() {
        let mut s = SortWorker::new(0, 0, bounds());
        let mut out = VecEmitter::default();
        for v in [5.0, 1.0, 9.0] {
            s.process(t1(v), 0, &mut out);
        }
        s.finish(&mut out);
        let vals: Vec<f64> = out.0.iter().map(|t| t.get(0).as_float().unwrap()).collect();
        assert_eq!(vals, vec![1.0, 5.0, 9.0]);
    }

    #[test]
    fn foreign_scope_tracked_separately() {
        // Worker 2 (scope 2: >20) receives redirected scope-0 tuples.
        let mut s = SortWorker::new(0, 2, bounds());
        let mut out = VecEmitter::default();
        s.process(t1(25.0), 0, &mut out); // own
        s.process(t1(3.0), 0, &mut out); // foreign (scope 0)
        assert_eq!(s.scattered_tuples(), 1);
    }

    #[test]
    fn scattered_state_merge_restores_order() {
        // Fig. 3.11: helper S3 ships its [0,10] run back to S1.
        let mut s1 = SortWorker::new(0, 0, bounds());
        let mut s3 = SortWorker::new(0, 2, bounds());
        let mut out = VecEmitter::default();
        s1.process(t1(7.0), 0, &mut out);
        s3.process(t1(2.0), 0, &mut out); // redirected [0,10] tuple
        s3.process(t1(25.0), 0, &mut out); // own range
        let scattered = s3.extract_state(Some(&[0]), false);
        s1.merge_state(scattered);
        assert_eq!(s3.scattered_tuples(), 0);
        let mut o1 = VecEmitter::default();
        s1.finish(&mut o1);
        let vals: Vec<f64> = o1.0.iter().map(|t| t.get(0).as_float().unwrap()).collect();
        assert_eq!(vals, vec![2.0, 7.0]);
    }

    #[test]
    fn merge_layer_total_order() {
        let mut m = SortMerge::new(0);
        let mut out = VecEmitter::default();
        for v in [9.0, 1.0, 5.0, 3.0] {
            m.process(t1(v), 0, &mut out);
        }
        m.finish(&mut out);
        let vals: Vec<f64> = out.0.iter().map(|t| t.get(0).as_float().unwrap()).collect();
        assert_eq!(vals, vec![1.0, 3.0, 5.0, 9.0]);
    }

    #[test]
    fn batched_absorb_matches_per_tuple() {
        let rows: Vec<Tuple> = [15.0, 3.0, 25.0, 8.0, 12.0].iter().map(|&v| t1(v)).collect();
        let batch = TupleBatch::from_columns(
            crate::column::ColumnSet::from_rows(&rows).expect("uniform rows"),
        );
        let mut sink = VecEmitter::default();
        let mut per = SortWorker::new(0, 1, bounds());
        let mut bat = SortWorker::new(0, 1, bounds());
        for r in &rows {
            per.process(r.clone(), 0, &mut sink);
        }
        bat.process_batch(&batch, 0, &mut sink);
        assert_eq!(per.scattered_tuples(), bat.scattered_tuples());
        let (mut o1, mut o2) = (VecEmitter::default(), VecEmitter::default());
        per.finish(&mut o1);
        bat.finish(&mut o2);
        assert_eq!(o1.0, o2.0);

        let mut m1 = SortMerge::new(0);
        let mut m2 = SortMerge::new(0);
        for r in &rows {
            m1.process(r.clone(), 0, &mut sink);
        }
        m2.process_batch(&batch, 0, &mut sink);
        let (mut mo1, mut mo2) = (VecEmitter::default(), VecEmitter::default());
        m1.finish(&mut mo1);
        m2.finish(&mut mo2);
        assert_eq!(mo1.0, mo2.0);
    }

    #[test]
    fn snapshot_restore_keeps_runs() {
        let mut s = SortWorker::new(0, 0, bounds());
        let mut out = VecEmitter::default();
        s.process(t1(4.0), 0, &mut out);
        let snap = s.snapshot();
        let mut s2 = SortWorker::new(0, 0, bounds());
        s2.restore(snap);
        assert_eq!(s2.state_size(), 1);
    }
}

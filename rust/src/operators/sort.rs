//! Distributed sort (§2.4.3 category 2): a range-partitioned first
//! layer sorts local runs; a single-worker second layer merges them.
//!
//! Sort is the paper's canonical *mutable-state* operator for Reshape
//! (§3.5.4): SBR splits a range across the skewed worker and a helper,
//! producing a **scattered state** — the helper accumulates a separate
//! sorted run for the foreign range and ships it back to the range's
//! owner when the input ends (the END-marker merge of Fig. 3.11). Both
//! conditions for scattered-state resolution hold: runs merge by
//! merging sorted lists, and sort blocks until EOF anyway.
//!
//! **Out-of-core** (see `docs/ARCHITECTURE.md` "Out-of-core
//! execution"): past the execution's memory budget either sort layer
//! stable-sorts its resident buffer and writes it out as one sorted
//! **run file**, repeatedly; EOF performs a streaming k-way merge over
//! all run files plus the sorted resident remainder. Ties prefer the
//! lower (scope, run-sequence) cursor, which reproduces the resident
//! path's stable concatenate-then-sort order exactly.

use crate::engine::operator::{Emitter, OpState, Operator};
use crate::engine::spill::{
    read_slot_rows, rows_byte_size, MemLease, SpillCtx, SpillFile, SpillReader, SpillSlot,
};
use crate::tuple::{value_cmp, Tuple, TupleBatch};
use std::collections::{BTreeMap, HashMap};

/// Spill-slot tag: a sort layer has one stream kind — sorted runs.
const TAG_RUN: u32 = 0;

/// Rows per spill frame when writing a run: bounds the memory a merge
/// cursor buffers per run (one frame) independently of run length.
const RUN_FRAME_ROWS: usize = 512;

/// Streaming cursor over one sorted run file.
struct RunCursor {
    reader: SpillReader,
    rows: std::vec::IntoIter<Tuple>,
    head: Option<Tuple>,
}

impl RunCursor {
    fn open(ctx: &SpillCtx, slot: &SpillSlot) -> RunCursor {
        let mut c = RunCursor {
            reader: SpillReader::open(ctx, slot),
            rows: Vec::new().into_iter(),
            head: None,
        };
        c.refill();
        c
    }

    fn refill(&mut self) {
        loop {
            if let Some(t) = self.rows.next() {
                self.head = Some(t);
                return;
            }
            match self.reader.next_rows() {
                Some(rows) => self.rows = rows.into_iter(),
                None => {
                    self.head = None;
                    return;
                }
            }
        }
    }

    fn pop(&mut self) -> Option<Tuple> {
        let t = self.head.take();
        if t.is_some() {
            self.refill();
        }
        t
    }
}

/// Per-layer external-sort state, shared by both sort layers. Without
/// an attached [`SpillCtx`] every method is a no-op and the resident
/// path is byte-identical to the pre-spill implementation.
#[derive(Default)]
struct SortSpill {
    ctx: Option<SpillCtx>,
    lease: MemLease,
    resident_bytes: u64,
    /// scope → run files in write (sequence) order.
    runs: BTreeMap<u64, Vec<SpillFile>>,
}

impl SortSpill {
    fn attach(&mut self, ctx: &SpillCtx) {
        self.lease = MemLease::new(ctx.budget.clone());
        self.ctx = Some(ctx.clone());
    }

    fn tracking(&self) -> bool {
        self.ctx.is_some()
    }

    fn note_rows(&mut self, bytes: u64) {
        self.resident_bytes += bytes;
    }

    fn has_runs(&self) -> bool {
        !self.runs.is_empty()
    }

    fn over(&mut self) -> bool {
        let Some(ctx) = &self.ctx else { return false };
        self.lease.set(self.resident_bytes);
        ctx.budget.over()
    }

    /// Stable-sort `rows` by the key field and write them as one run
    /// file for `scope`, in [`RUN_FRAME_ROWS`]-row frames.
    fn write_run(&mut self, scope: u64, mut rows: Vec<Tuple>, key_field: usize) {
        if rows.is_empty() {
            return;
        }
        let ctx = self.ctx.clone().expect("spill ctx attached");
        rows.sort_by(|a, b| value_cmp(a.get(key_field), b.get(key_field)));
        let files = self.runs.entry(scope).or_default();
        let seq = files.len() as u64;
        if seq == 0 {
            ctx.counters.add_partition();
        }
        let mut f = SpillFile::create(&ctx, TAG_RUN, scope, seq);
        for chunk in rows.chunks(RUN_FRAME_ROWS) {
            f.append(chunk);
        }
        files.push(f);
    }

    fn reset_resident(&mut self, bytes: u64) {
        if !self.tracking() {
            return;
        }
        self.resident_bytes = bytes;
        self.lease.set(self.resident_bytes);
    }

    /// Read every run back into memory, per scope in sequence order —
    /// state-extraction paths (migration/scale) work on resident
    /// state. Files stay on disk, orphaned, until directory teardown.
    fn unspill(&mut self) -> Vec<(u64, Vec<Tuple>)> {
        let Some(ctx) = self.ctx.clone() else { return Vec::new() };
        let mut out = Vec::new();
        for (scope, files) in std::mem::take(&mut self.runs) {
            let mut rows = Vec::new();
            for f in files {
                rows.extend(read_slot_rows(&ctx, &f.slot()));
            }
            out.push((scope, rows));
        }
        out
    }

    fn snapshot_slots(&self) -> Vec<SpillSlot> {
        self.runs
            .values()
            .flat_map(|files| files.iter().map(|f| f.slot()))
            .collect()
    }

    fn restore_slots(&mut self, mut slots: Vec<SpillSlot>) {
        self.runs.clear();
        if slots.is_empty() {
            return;
        }
        let ctx = self.ctx.clone().expect("spill ctx attached before restore");
        slots.sort_by_key(|s| (s.scope, s.seq));
        for slot in slots {
            self.runs
                .entry(slot.scope)
                .or_default()
                .push(SpillFile::reopen(&ctx, &slot));
        }
    }

    /// Streaming k-way merge over every run file, emitting in key
    /// order. Ties prefer the earliest cursor — cursors are ordered by
    /// (scope, sequence), reproducing the resident path's stable
    /// concatenate-then-sort order.
    fn merge_emit(&mut self, key_field: usize, out: &mut dyn Emitter) {
        let ctx = self.ctx.clone().expect("spill ctx attached");
        let mut cursors: Vec<RunCursor> = Vec::new();
        for files in std::mem::take(&mut self.runs).into_values() {
            for f in files {
                cursors.push(RunCursor::open(&ctx, &f.slot()));
            }
        }
        loop {
            let mut best: Option<usize> = None;
            for (i, c) in cursors.iter().enumerate() {
                let Some(h) = &c.head else { continue };
                match best {
                    None => best = Some(i),
                    Some(b) => {
                        let bh = cursors[b].head.as_ref().unwrap();
                        if value_cmp(h.get(key_field), bh.get(key_field))
                            == std::cmp::Ordering::Less
                        {
                            best = Some(i);
                        }
                    }
                }
            }
            let Some(i) = best else { break };
            out.emit(cursors[i].pop().unwrap());
        }
    }
}

/// First-layer sorter: accumulates tuples, sorts at EOF, emits the run.
///
/// `scope_of` assigns each tuple a *scope id* (its range index under
/// the plan's range partitioning). Tuples whose scope is not
/// `own_scope` are foreign (the scattered part created by SBR
/// mitigation) and are kept in separate per-scope runs.
pub struct SortWorker {
    pub key_field: usize,
    /// This worker's own range index.
    pub own_scope: u64,
    /// Range upper bounds (same as the partitioner's) for scope
    /// computation; scope = first bound ≥ value.
    pub bounds: Vec<crate::tuple::Value>,
    /// Artificial per-tuple insertion cost in ns (models the paper's
    /// heavier sort workers; 0 = none).
    pub cost_ns: u64,
    runs: HashMap<u64, Vec<Tuple>>,
    spill: SortSpill,
}

impl SortWorker {
    pub fn new(key_field: usize, own_scope: u64, bounds: Vec<crate::tuple::Value>) -> SortWorker {
        SortWorker {
            key_field,
            own_scope,
            bounds,
            cost_ns: 0,
            runs: HashMap::new(),
            spill: SortSpill::default(),
        }
    }

    /// Builder: artificial per-tuple cost.
    pub fn with_cost(mut self, ns: u64) -> SortWorker {
        self.cost_ns = ns;
        self
    }

    fn scope_of(&self, t: &Tuple) -> u64 {
        let v = t.get(self.key_field);
        for (i, b) in self.bounds.iter().enumerate() {
            if value_cmp(v, b) != std::cmp::Ordering::Greater {
                return i as u64;
            }
        }
        self.bounds.len() as u64
    }

    /// Tuples held for foreign scopes (scattered state size).
    pub fn scattered_tuples(&self) -> usize {
        self.runs
            .iter()
            .filter(|(s, _)| **s != self.own_scope)
            .map(|(_, v)| v.len())
            .sum()
    }

    /// Evict every resident scope buffer as one sorted run each when
    /// over budget.
    fn maybe_spill(&mut self) {
        if !self.spill.over() {
            return;
        }
        let mut scopes: Vec<u64> = self.runs.keys().copied().collect();
        scopes.sort_unstable();
        for s in scopes {
            let rows = std::mem::take(self.runs.get_mut(&s).unwrap());
            self.spill.write_run(s, rows, self.key_field);
        }
        self.runs.retain(|_, v| !v.is_empty());
        self.spill.reset_resident(0);
    }

    /// Read spilled runs back into the resident per-scope buffers
    /// before state extraction. Equal keys keep their arrival-relative
    /// order (runs are stable-sorted arrival segments, re-appended in
    /// sequence order), so the EOF stable sort still ties identically.
    fn unspill(&mut self) {
        for (scope, rows) in self.spill.unspill() {
            self.runs.entry(scope).or_default().extend(rows);
        }
        let bytes = self.runs.values().map(|v| rows_byte_size(v)).sum();
        self.spill.reset_resident(bytes);
    }
}

impl Operator for SortWorker {
    fn name(&self) -> &str {
        "sort_worker"
    }

    fn blocking_ports(&self) -> Vec<usize> {
        vec![0]
    }

    fn attach_spill(&mut self, ctx: &SpillCtx) {
        self.spill.attach(ctx);
    }

    fn process(&mut self, t: Tuple, _port: usize, _out: &mut dyn Emitter) {
        if self.cost_ns > 0 {
            let t0 = std::time::Instant::now();
            while (t0.elapsed().as_nanos() as u64) < self.cost_ns {
                std::hint::spin_loop();
            }
        }
        let scope = self.scope_of(&t);
        if self.spill.tracking() {
            self.spill.note_rows(t.byte_size() as u64);
        }
        self.runs.entry(scope).or_default().push(t);
        self.maybe_spill();
    }

    /// Batch absorb: one combined spin (chunk length × per-tuple cost)
    /// and one dispatch per chunk. Sort state stays row-major
    /// (`Vec<Tuple>` runs feed a comparison sort at EOF), so the batch
    /// win here is amortized dispatch and a single cost spin — the
    /// typed-column kernels don't apply.
    fn process_batch(&mut self, batch: &TupleBatch, _port: usize, _out: &mut dyn Emitter) {
        if self.cost_ns > 0 && !batch.is_empty() {
            let total = self.cost_ns * batch.len() as u64;
            let t0 = std::time::Instant::now();
            while (t0.elapsed().as_nanos() as u64) < total {
                std::hint::spin_loop();
            }
        }
        let track = self.spill.tracking();
        for t in batch.iter() {
            let scope = self.scope_of(t);
            if track {
                self.spill.note_rows(t.byte_size() as u64);
            }
            self.runs.entry(scope).or_default().push(t.clone());
        }
        self.maybe_spill();
    }

    fn finish(&mut self, out: &mut dyn Emitter) {
        // At EOF, only the own-scope run should remain (the engine's
        // Reshape layer migrates foreign runs back to their owners
        // before EOF cascades); any still-foreign tuples are emitted
        // too so no data is lost even without mitigation.
        if self.spill.has_runs() {
            // Flush the resident remainder as final runs, then k-way
            // merge everything off disk.
            let mut scopes: Vec<u64> = self.runs.keys().copied().collect();
            scopes.sort_unstable();
            for s in scopes {
                let rows = std::mem::take(self.runs.get_mut(&s).unwrap());
                self.spill.write_run(s, rows, self.key_field);
            }
            self.runs.clear();
            self.spill.reset_resident(0);
            self.spill.merge_emit(self.key_field, out);
            return;
        }
        let mut scopes: Vec<u64> = self.runs.keys().copied().collect();
        scopes.sort_unstable();
        let mut all: Vec<Tuple> = Vec::new();
        for s in scopes {
            all.append(self.runs.get_mut(&s).unwrap());
        }
        all.sort_by(|a, b| value_cmp(a.get(self.key_field), b.get(self.key_field)));
        for t in all {
            out.emit(t);
        }
    }

    fn snapshot(&self) -> OpState {
        let mut s = OpState::default();
        s.keyed_tuples = self.runs.clone();
        s.spill = self.spill.snapshot_slots();
        s
    }

    fn restore(&mut self, mut s: OpState) {
        self.spill.restore_slots(std::mem::take(&mut s.spill));
        self.runs = s.keyed_tuples;
        let bytes = self.runs.values().map(|v| rows_byte_size(v)).sum();
        self.spill.reset_resident(bytes);
    }

    fn state_size(&self) -> usize {
        self.runs.values().map(Vec::len).sum()
    }

    fn extract_state(&mut self, keys: Option<&[u64]>, replicate: bool) -> OpState {
        self.unspill();
        // keys here are *scope ids* (range indexes), not value hashes.
        let mut out = OpState::default();
        let targets: Vec<u64> = match keys {
            None => self.runs.keys().copied().collect(),
            Some(ks) => ks.to_vec(),
        };
        for k in targets {
            let item = if replicate {
                self.runs.get(&k).cloned()
            } else {
                self.runs.remove(&k)
            };
            if let Some(v) = item {
                out.keyed_tuples.insert(k, v);
            }
        }
        let bytes = self.runs.values().map(|v| rows_byte_size(v)).sum();
        self.spill.reset_resident(bytes);
        out
    }

    fn merge_state(&mut self, s: OpState) {
        for (k, mut v) in s.keyed_tuples {
            if self.spill.tracking() {
                self.spill.note_rows(rows_byte_size(&v));
            }
            self.runs.entry(k).or_default().append(&mut v);
        }
        self.maybe_spill();
    }

    fn state_mutable(&self) -> bool {
        true
    }

    /// Elastic scaling: adopt the new placement and re-derive the range
    /// bounds with the same interpolation the coordinator applies to
    /// the upstream `Range` partitioner
    /// ([`rescale_bounds`](crate::engine::scale::rescale_bounds)), so
    /// future tuples keep classifying own-vs-foreign consistently with
    /// where the exchange actually sends them. Runs accumulated under
    /// old scope ids stay keyed as they are — the foreign-run fallback
    /// in `finish`/`scattered_parts` emits or ships them
    /// regardless, so the output multiset is unaffected either way;
    /// this hook only prevents a resized worker set from classifying
    /// *all* new input as foreign and funneling it back through the
    /// old workers at EOF.
    fn rescale(&mut self, idx: usize, workers: usize) {
        self.own_scope = idx as u64;
        self.bounds = crate::engine::scale::rescale_bounds(&self.bounds, workers);
    }

    fn scattered_parts(&mut self) -> Vec<(u64, OpState)> {
        // Foreign runs (scopes ≠ own) are shipped back to their owners
        // at EOF (Fig. 3.11(e,f)); scope id == owner worker index
        // under range partitioning. Spilled runs may hold foreign
        // tuples too, so read them back first.
        if self.spill.has_runs() {
            self.unspill();
        }
        let foreign: Vec<u64> = self
            .runs
            .keys()
            .copied()
            .filter(|s| *s != self.own_scope)
            .collect();
        foreign
            .into_iter()
            .map(|scope| {
                let mut st = OpState::default();
                st.keyed_tuples
                    .insert(scope, self.runs.remove(&scope).unwrap());
                (scope, st)
            })
            .collect()
    }
}

/// Second-layer merger: single worker; collects sorted runs from all
/// first-layer workers and merges them at EOF. Input arrives
/// interleaved, so it re-sorts (equivalent to a k-way merge; runs are
/// concatenated then sorted with a stable O(n log n) sort — adequate at
/// our scale and deterministic). Past the memory budget the buffer is
/// evicted as sorted run files merged streamingly at EOF.
pub struct SortMerge {
    pub key_field: usize,
    buffer: Vec<Tuple>,
    spill: SortSpill,
}

impl SortMerge {
    pub fn new(key_field: usize) -> SortMerge {
        SortMerge { key_field, buffer: Vec::new(), spill: SortSpill::default() }
    }

    fn maybe_spill(&mut self) {
        if !self.spill.over() || self.buffer.is_empty() {
            return;
        }
        let rows = std::mem::take(&mut self.buffer);
        self.spill.write_run(0, rows, self.key_field);
        self.spill.reset_resident(0);
    }
}

impl Operator for SortMerge {
    fn name(&self) -> &str {
        "sort_merge"
    }

    fn blocking_ports(&self) -> Vec<usize> {
        vec![0]
    }

    fn attach_spill(&mut self, ctx: &SpillCtx) {
        self.spill.attach(ctx);
    }

    fn process(&mut self, t: Tuple, _port: usize, _out: &mut dyn Emitter) {
        if self.spill.tracking() {
            self.spill.note_rows(t.byte_size() as u64);
        }
        self.buffer.push(t);
        self.maybe_spill();
    }

    /// Bulk absorb: extend the merge buffer in one call instead of one
    /// virtual dispatch per tuple.
    fn process_batch(&mut self, batch: &TupleBatch, _port: usize, _out: &mut dyn Emitter) {
        if self.spill.tracking() {
            self.spill.note_rows(batch.iter().map(|t| t.byte_size() as u64).sum());
        }
        self.buffer.extend(batch.iter().cloned());
        self.maybe_spill();
    }

    fn finish(&mut self, out: &mut dyn Emitter) {
        if self.spill.has_runs() {
            let rows = std::mem::take(&mut self.buffer);
            self.spill.write_run(0, rows, self.key_field);
            self.spill.reset_resident(0);
            self.spill.merge_emit(self.key_field, out);
            return;
        }
        self.buffer
            .sort_by(|a, b| value_cmp(a.get(self.key_field), b.get(self.key_field)));
        for t in self.buffer.drain(..) {
            out.emit(t);
        }
    }

    fn snapshot(&self) -> OpState {
        let mut s = OpState::default();
        s.keyed_tuples.insert(0, self.buffer.clone());
        s.spill = self.spill.snapshot_slots();
        s
    }

    fn restore(&mut self, mut s: OpState) {
        self.spill.restore_slots(std::mem::take(&mut s.spill));
        self.buffer = s.keyed_tuples.remove(&0).unwrap_or_default();
        self.spill.reset_resident(rows_byte_size(&self.buffer));
    }

    fn state_size(&self) -> usize {
        self.buffer.len()
    }

    /// Elastic scaling migrates the merge buffer whole (scope 0): the
    /// merge layer re-sorts everything at EOF, so which worker holds
    /// which run never affects the output order.
    fn extract_state(&mut self, _keys: Option<&[u64]>, replicate: bool) -> OpState {
        for (_, rows) in self.spill.unspill() {
            self.buffer.extend(rows);
        }
        let mut s = OpState::default();
        let buf = if replicate {
            self.buffer.clone()
        } else {
            std::mem::take(&mut self.buffer)
        };
        self.spill.reset_resident(rows_byte_size(&self.buffer));
        if !buf.is_empty() {
            s.keyed_tuples.insert(0, buf);
        }
        s
    }

    fn merge_state(&mut self, mut s: OpState) {
        for (_, mut v) in s.keyed_tuples.drain() {
            if self.spill.tracking() {
                self.spill.note_rows(rows_byte_size(&v));
            }
            self.buffer.append(&mut v);
        }
        self.maybe_spill();
    }

    fn state_mutable(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::engine::operator::VecEmitter;
    use crate::tuple::Value;

    fn t1(v: f64) -> Tuple {
        Tuple::new(vec![Value::Float(v)])
    }

    fn bounds() -> Vec<Value> {
        vec![Value::Float(10.0), Value::Float(20.0)]
    }

    #[test]
    fn sorts_own_range() {
        let mut s = SortWorker::new(0, 0, bounds());
        let mut out = VecEmitter::default();
        for v in [5.0, 1.0, 9.0] {
            s.process(t1(v), 0, &mut out);
        }
        s.finish(&mut out);
        let vals: Vec<f64> = out.0.iter().map(|t| t.get(0).as_float().unwrap()).collect();
        assert_eq!(vals, vec![1.0, 5.0, 9.0]);
    }

    #[test]
    fn foreign_scope_tracked_separately() {
        // Worker 2 (scope 2: >20) receives redirected scope-0 tuples.
        let mut s = SortWorker::new(0, 2, bounds());
        let mut out = VecEmitter::default();
        s.process(t1(25.0), 0, &mut out); // own
        s.process(t1(3.0), 0, &mut out); // foreign (scope 0)
        assert_eq!(s.scattered_tuples(), 1);
    }

    #[test]
    fn scattered_state_merge_restores_order() {
        // Fig. 3.11: helper S3 ships its [0,10] run back to S1.
        let mut s1 = SortWorker::new(0, 0, bounds());
        let mut s3 = SortWorker::new(0, 2, bounds());
        let mut out = VecEmitter::default();
        s1.process(t1(7.0), 0, &mut out);
        s3.process(t1(2.0), 0, &mut out); // redirected [0,10] tuple
        s3.process(t1(25.0), 0, &mut out); // own range
        let scattered = s3.extract_state(Some(&[0]), false);
        s1.merge_state(scattered);
        assert_eq!(s3.scattered_tuples(), 0);
        let mut o1 = VecEmitter::default();
        s1.finish(&mut o1);
        let vals: Vec<f64> = o1.0.iter().map(|t| t.get(0).as_float().unwrap()).collect();
        assert_eq!(vals, vec![2.0, 7.0]);
    }

    #[test]
    fn merge_layer_total_order() {
        let mut m = SortMerge::new(0);
        let mut out = VecEmitter::default();
        for v in [9.0, 1.0, 5.0, 3.0] {
            m.process(t1(v), 0, &mut out);
        }
        m.finish(&mut out);
        let vals: Vec<f64> = out.0.iter().map(|t| t.get(0).as_float().unwrap()).collect();
        assert_eq!(vals, vec![1.0, 3.0, 5.0, 9.0]);
    }

    #[test]
    fn batched_absorb_matches_per_tuple() {
        let rows: Vec<Tuple> = [15.0, 3.0, 25.0, 8.0, 12.0].iter().map(|&v| t1(v)).collect();
        let batch = TupleBatch::from_columns(
            crate::column::ColumnSet::from_rows(&rows).expect("uniform rows"),
        );
        let mut sink = VecEmitter::default();
        let mut per = SortWorker::new(0, 1, bounds());
        let mut bat = SortWorker::new(0, 1, bounds());
        for r in &rows {
            per.process(r.clone(), 0, &mut sink);
        }
        bat.process_batch(&batch, 0, &mut sink);
        assert_eq!(per.scattered_tuples(), bat.scattered_tuples());
        let (mut o1, mut o2) = (VecEmitter::default(), VecEmitter::default());
        per.finish(&mut o1);
        bat.finish(&mut o2);
        assert_eq!(o1.0, o2.0);

        let mut m1 = SortMerge::new(0);
        let mut m2 = SortMerge::new(0);
        for r in &rows {
            m1.process(r.clone(), 0, &mut sink);
        }
        m2.process_batch(&batch, 0, &mut sink);
        let (mut mo1, mut mo2) = (VecEmitter::default(), VecEmitter::default());
        m1.finish(&mut mo1);
        m2.finish(&mut mo2);
        assert_eq!(mo1.0, mo2.0);
    }

    #[test]
    fn snapshot_restore_keeps_runs() {
        let mut s = SortWorker::new(0, 0, bounds());
        let mut out = VecEmitter::default();
        s.process(t1(4.0), 0, &mut out);
        let snap = s.snapshot();
        let mut s2 = SortWorker::new(0, 0, bounds());
        s2.restore(snap);
        assert_eq!(s2.state_size(), 1);
    }

    // ---- out-of-core ----

    fn tiny_ctx(limit: u64) -> SpillCtx {
        let mut cfg = Config::for_tests();
        cfg.memory_budget_bytes = limit;
        SpillCtx::new(&cfg)
    }

    fn wide_bounds() -> Vec<Value> {
        vec![Value::Float(1e9)]
    }

    #[test]
    fn spilled_sort_matches_unbounded_exactly() {
        // Duplicate keys included: the run-merge tie-break must
        // reproduce the stable resident sort byte for byte.
        let rows: Vec<Tuple> = (0..600)
            .map(|i| Tuple::new(vec![Value::Float((i % 53) as f64), Value::Int(i)]))
            .collect();
        let mut plain = SortWorker::new(0, 0, wide_bounds());
        let mut o1 = VecEmitter::default();
        for t in &rows {
            plain.process(t.clone(), 0, &mut o1);
        }
        plain.finish(&mut o1);

        let ctx = tiny_ctx(512);
        let mut spilled = SortWorker::new(0, 0, wide_bounds());
        spilled.attach_spill(&ctx);
        let mut o2 = VecEmitter::default();
        for t in &rows {
            spilled.process(t.clone(), 0, &mut o2);
        }
        spilled.finish(&mut o2);
        assert_eq!(o1.0, o2.0, "spilled sort must be byte-identical");
        let stats = ctx.counters.snapshot(&ctx.budget);
        assert!(stats.bytes_spilled > 0, "tiny budget must spill");
    }

    #[test]
    fn spilled_merge_layer_matches_unbounded_exactly() {
        let rows: Vec<Tuple> = (0..600)
            .map(|i| Tuple::new(vec![Value::Float(((i * 7) % 91) as f64), Value::Int(i)]))
            .collect();
        let mut plain = SortMerge::new(0);
        let mut o1 = VecEmitter::default();
        for t in &rows {
            plain.process(t.clone(), 0, &mut o1);
        }
        plain.finish(&mut o1);

        let ctx = tiny_ctx(512);
        let mut spilled = SortMerge::new(0);
        spilled.attach_spill(&ctx);
        let mut o2 = VecEmitter::default();
        for t in &rows {
            spilled.process(t.clone(), 0, &mut o2);
        }
        spilled.finish(&mut o2);
        assert_eq!(o1.0, o2.0);
    }

    #[test]
    fn spilled_snapshot_restores_byte_exact() {
        let rows: Vec<Tuple> = (0..400)
            .map(|i| Tuple::new(vec![Value::Float((i % 37) as f64), Value::Int(i)]))
            .collect();
        let mut plain = SortWorker::new(0, 0, wide_bounds());
        let mut o1 = VecEmitter::default();
        for t in &rows {
            plain.process(t.clone(), 0, &mut o1);
        }
        plain.finish(&mut o1);

        let ctx = tiny_ctx(512);
        let mut s = SortWorker::new(0, 0, wide_bounds());
        s.attach_spill(&ctx);
        let mut sink = VecEmitter::default();
        for t in &rows {
            s.process(t.clone(), 0, &mut sink);
        }
        let snap = s.snapshot();
        assert!(!snap.spill.is_empty(), "manifest carries run files");
        // Post-snapshot rows must be truncated away by restore.
        s.process(t1(-1.0), 0, &mut sink);
        let mut s2 = SortWorker::new(0, 0, wide_bounds());
        s2.attach_spill(&ctx);
        s2.restore(snap);
        let mut o2 = VecEmitter::default();
        s2.finish(&mut o2);
        assert_eq!(o1.0, o2.0);
    }

    #[test]
    fn spilled_extract_sees_all_rows() {
        let ctx = tiny_ctx(256);
        let mut s = SortWorker::new(0, 0, wide_bounds());
        s.attach_spill(&ctx);
        let mut sink = VecEmitter::default();
        for i in 0..300 {
            s.process(t1(i as f64), 0, &mut sink);
        }
        assert!(s.spill.has_runs(), "must have spilled");
        let st = s.extract_state(None, false);
        let total: usize = st.keyed_tuples.values().map(Vec::len).sum();
        assert_eq!(total, 300, "extraction sees spilled + resident rows");
        assert_eq!(s.state_size(), 0);
    }
}

//! The physical-operator library: relational operators plus the ML
//! inference operator, all written against the engine's iteration model
//! (§2.4.3) so they pause/resume, checkpoint and migrate state.
//!
//! State-mutability classification (Table 3.1):
//!
//! | Operator | phase | state |
//! |---|---|---|
//! | [`hash_join::HashJoin`] | build | mutable |
//! | [`hash_join::HashJoin`] | probe | immutable |
//! | [`enrich::Enrich`] | dict | mutable (broadcast + partitioned counts) |
//! | [`group_by::GroupByPartial`]/[`group_by::GroupByFinal`] | — | mutable |
//! | [`sort::SortWorker`]/[`sort::SortMerge`] | — | mutable |
//! | [`basic`] (filter, project, keyword, parser, UDF map) | — | stateless |

pub mod basic;
pub mod enrich;
pub mod hash_join;
pub mod group_by;
pub mod sort;
pub mod sink;
pub mod ml_infer;

pub use basic::{Filter, KeywordSearch, MapUdf, Project, RegexParser, Union};
pub use enrich::Enrich;
pub use group_by::{AggKind, GroupByFinal, GroupByPartial};
pub use hash_join::HashJoin;
pub use sink::{CollectSink, CountByKeySink, SinkHandle};
pub use sort::{SortMerge, SortWorker};

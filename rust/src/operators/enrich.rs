//! Mixed-port broadcast enrichment: a broadcast dictionary **plus**
//! hash-partitioned per-key state in one operator.
//!
//! Port 0 (**dict**, blocking, `Broadcast`) streams `[key, bonus]`
//! rows that every worker replicates into a lookup table. Port 1
//! (**events**, `Hash{0}`) streams `[key, val]` rows; each emits
//! `[key, val + bonus(key), 1]` and bumps a per-key counter, and at
//! EOF every counted key emits a `[key, count, -1]` summary row.
//!
//! The per-key counters are **partitioned-port state**: correctness
//! depends on `stable_hash(key) % n` colocation with the event port's
//! hash routing — exactly the state the broadcast scale path's
//! [`Operator::partitioned_state`] sweep must re-shard when the worker
//! set changes. A broadcast-only-state operator (e.g.
//! [`crate::operators::HashJoin`] with a broadcast build side) keeps
//! its default empty sweep; this operator is the regression surface
//! for the replicate/retire path's former broadcast-only-state
//! assumption.

use crate::engine::operator::{Emitter, OpState, Operator};
use crate::tuple::{Tuple, Value};
use std::collections::HashMap;

/// Dictionary port index (blocking, broadcast).
pub const DICT: usize = 0;
/// Event port index (hash-partitioned).
pub const EVENT: usize = 1;

/// State-encoding tags: dict rows vs. count rows inside one
/// [`OpState`] (both live in `keyed_tuples`, keyed by the same
/// `stable_hash(key)` space).
const TAG_DICT: &str = "d";
const TAG_COUNT: &str = "c";

#[derive(Default)]
pub struct Enrich {
    /// Broadcast-replicated: key hash → (key, bonus).
    dict: HashMap<u64, (Value, i64)>,
    dict_done: bool,
    /// Hash-partitioned: key hash → (key, event count). Colocated with
    /// the event port's `Hash{0}` routing.
    counts: HashMap<u64, (Value, i64)>,
    /// Events that arrived before dict EOF (buffering mode, like the
    /// join's early-probe buffer).
    early: Vec<Tuple>,
}

impl Enrich {
    pub fn new() -> Enrich {
        Enrich::default()
    }

    fn apply_event(&mut self, t: &Tuple, out: &mut dyn Emitter) {
        let key = t.get(0);
        let h = key.stable_hash();
        let bonus = self.dict.get(&h).map(|(_, b)| *b).unwrap_or(0);
        let val = t.get(1).as_int().unwrap_or(0);
        out.emit(Tuple::new(vec![
            key.clone(),
            Value::Int(val + bonus),
            Value::Int(1),
        ]));
        let e = self.counts.entry(h).or_insert_with(|| (key.clone(), 0));
        e.1 += 1;
    }

    fn tagged(tag: &str, key: &Value, n: i64) -> Tuple {
        Tuple::new(vec![Value::str(tag), key.clone(), Value::Int(n)])
    }

    /// Fold tagged state rows into the live maps (dict rows merge by
    /// last-write, count rows sum — shard installs and checkpoint
    /// restores share this decoder).
    fn absorb_tagged(&mut self, s: &OpState) {
        for rows in s.keyed_tuples.values() {
            for t in rows {
                let tag = t.get(0).as_str().unwrap_or("");
                let key = t.get(1);
                let n = t.get(2).as_int().unwrap_or(0);
                let h = key.stable_hash();
                match tag {
                    TAG_DICT => {
                        self.dict.insert(h, (key.clone(), n));
                    }
                    TAG_COUNT => {
                        let e = self
                            .counts
                            .entry(h)
                            .or_insert_with(|| (key.clone(), 0));
                        e.1 += n;
                    }
                    _ => {}
                }
            }
        }
    }
}

impl Operator for Enrich {
    fn name(&self) -> &str {
        "enrich"
    }

    fn num_ports(&self) -> usize {
        2
    }

    fn blocking_ports(&self) -> Vec<usize> {
        vec![DICT]
    }

    fn process(&mut self, t: Tuple, port: usize, out: &mut dyn Emitter) {
        match port {
            DICT => {
                let h = t.get(0).stable_hash();
                let bonus = t.get(1).as_int().unwrap_or(0);
                self.dict.insert(h, (t.get(0).clone(), bonus));
            }
            EVENT => {
                if self.dict_done {
                    self.apply_event(&t, out);
                } else {
                    self.early.push(t);
                }
            }
            _ => unreachable!("enrich has 2 ports"),
        }
    }

    fn finish_port(&mut self, port: usize, out: &mut dyn Emitter) {
        if port == DICT {
            self.dict_done = true;
            let buffered = std::mem::take(&mut self.early);
            for t in &buffered {
                self.apply_event(t, out);
            }
        }
    }

    fn finish(&mut self, out: &mut dyn Emitter) {
        // Per-key summaries, hash-ordered for determinism within a
        // worker (cross-worker order is a multiset anyway).
        let mut keys: Vec<u64> = self.counts.keys().copied().collect();
        keys.sort_unstable();
        for h in keys {
            let (key, n) = &self.counts[&h];
            out.emit(Tuple::new(vec![
                key.clone(),
                Value::Int(*n),
                Value::Int(-1),
            ]));
        }
    }

    fn snapshot(&self) -> OpState {
        let mut s = OpState::default();
        for (h, (k, b)) in &self.dict {
            s.keyed_tuples
                .entry(*h)
                .or_default()
                .push(Self::tagged(TAG_DICT, k, *b));
        }
        for (h, (k, n)) in &self.counts {
            s.keyed_tuples
                .entry(*h)
                .or_default()
                .push(Self::tagged(TAG_COUNT, k, *n));
        }
        if !self.early.is_empty() {
            s.keyed_tuples
                .entry(u64::MAX) // sentinel scope for the early buffer
                .or_default()
                .extend(self.early.iter().cloned());
        }
        s.counters.insert("dict_done".into(), self.dict_done as i64);
        s
    }

    fn restore(&mut self, mut s: OpState) {
        self.dict.clear();
        self.counts.clear();
        self.early = s.keyed_tuples.remove(&u64::MAX).unwrap_or_default();
        self.dict_done = s.counters.get("dict_done").copied().unwrap_or(0) != 0;
        self.absorb_tagged(&s);
    }

    fn state_size(&self) -> usize {
        self.dict.len() + self.counts.len() + self.early.len()
    }

    fn extract_state(&mut self, _keys: Option<&[u64]>, replicate: bool) -> OpState {
        let s = self.snapshot();
        if !replicate {
            self.dict.clear();
            self.counts.clear();
            self.early.clear();
        }
        s
    }

    fn merge_state(&mut self, s: OpState) {
        self.absorb_tagged(&s);
        // A helper receiving event-port state is past dict EOF (the
        // skewed worker only migrates once its own dict is complete).
        self.dict_done = true;
    }

    fn install_state(&mut self, s: OpState) {
        // Shard install (re-shard sweep / scale): tagged rows only,
        // keep this worker's own phase.
        self.absorb_tagged(&s);
    }

    /// Broadcast replica: the dictionary and its EOF flag — **not**
    /// the per-key counts (partitioned; replicating them would
    /// double-count) and not the early buffer (events are partitioned
    /// per worker).
    fn replicate_broadcast_state(&self) -> OpState {
        let mut s = OpState::default();
        for (h, (k, b)) in &self.dict {
            s.keyed_tuples
                .entry(*h)
                .or_default()
                .push(Self::tagged(TAG_DICT, k, *b));
        }
        s.counters.insert("dict_done".into(), self.dict_done as i64);
        s
    }

    fn install_replica(&mut self, s: OpState) {
        self.dict_done = s.counters.get("dict_done").copied().unwrap_or(0) != 0;
        self.dict.clear();
        self.absorb_tagged(&s);
    }

    /// The per-key counters are the partitioned-port state the
    /// broadcast scale fence sweeps and re-shards over the new worker
    /// set (`stable_hash(key) % n` colocation with event routing).
    fn partitioned_state(&mut self) -> OpState {
        let mut s = OpState::default();
        for (h, (k, n)) in std::mem::take(&mut self.counts) {
            s.keyed_tuples
                .entry(h)
                .or_default()
                .push(Self::tagged(TAG_COUNT, &k, n));
        }
        s
    }

    /// Early events are re-routable input, not keyed state.
    fn drain_buffered_input(&mut self) -> Vec<(usize, Vec<Tuple>)> {
        if self.early.is_empty() {
            Vec::new()
        } else {
            vec![(EVENT, std::mem::take(&mut self.early))]
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::operator::VecEmitter;

    fn kv(k: i64, v: i64) -> Tuple {
        Tuple::new(vec![Value::Int(k), Value::Int(v)])
    }

    fn run_dict(e: &mut Enrich, rows: &[(i64, i64)], out: &mut VecEmitter) {
        for (k, b) in rows {
            e.process(kv(*k, *b), DICT, out);
        }
        e.finish_port(DICT, out);
    }

    #[test]
    fn enriches_and_counts() {
        let mut e = Enrich::new();
        let mut out = VecEmitter::default();
        run_dict(&mut e, &[(1, 100), (2, 200)], &mut out);
        e.process(kv(1, 5), EVENT, &mut out);
        e.process(kv(1, 6), EVENT, &mut out);
        e.process(kv(3, 7), EVENT, &mut out); // no dict entry: bonus 0
        e.finish(&mut out);
        let events: Vec<(i64, i64)> = out
            .0
            .iter()
            .filter(|t| t.get(2).as_int() == Some(1))
            .map(|t| (t.get(0).as_int().unwrap(), t.get(1).as_int().unwrap()))
            .collect();
        assert_eq!(events, vec![(1, 105), (1, 106), (3, 7)]);
        let mut counts: Vec<(i64, i64)> = out
            .0
            .iter()
            .filter(|t| t.get(2).as_int() == Some(-1))
            .map(|t| (t.get(0).as_int().unwrap(), t.get(1).as_int().unwrap()))
            .collect();
        counts.sort_unstable();
        assert_eq!(counts, vec![(1, 2), (3, 1)]);
    }

    #[test]
    fn early_events_buffer_until_dict_eof() {
        let mut e = Enrich::new();
        let mut out = VecEmitter::default();
        e.process(kv(1, 5), EVENT, &mut out);
        assert_eq!(out.0.len(), 0);
        run_dict(&mut e, &[(1, 10)], &mut out);
        assert_eq!(out.0.len(), 1, "buffered event replayed at dict EOF");
        assert_eq!(out.0[0].get(1).as_int(), Some(15));
    }

    #[test]
    fn partitioned_state_moves_counts_only() {
        let mut e = Enrich::new();
        let mut out = VecEmitter::default();
        run_dict(&mut e, &[(1, 10)], &mut out);
        e.process(kv(1, 1), EVENT, &mut out);
        let swept = e.partitioned_state();
        assert_eq!(swept.keyed_tuples.len(), 1);
        assert!(e.counts.is_empty(), "counts surrendered");
        assert!(!e.dict.is_empty(), "dict kept");
        // Re-install (possibly on another worker) and keep counting.
        let mut e2 = Enrich::new();
        e2.dict_done = true;
        e2.install_state(swept);
        e2.process(kv(1, 2), EVENT, &mut out);
        e2.finish(&mut out);
        let summary: Vec<i64> = out
            .0
            .iter()
            .filter(|t| t.get(2).as_int() == Some(-1))
            .map(|t| t.get(1).as_int().unwrap())
            .collect();
        assert_eq!(summary, vec![2], "counts summed across the sweep");
    }

    #[test]
    fn replica_excludes_partitioned_counts() {
        let mut e = Enrich::new();
        let mut out = VecEmitter::default();
        run_dict(&mut e, &[(1, 10)], &mut out);
        e.process(kv(1, 1), EVENT, &mut out);
        let rep = e.replicate_broadcast_state();
        let mut e2 = Enrich::new();
        e2.install_replica(rep);
        assert!(e2.dict_done);
        assert!(e2.counts.is_empty(), "replica carries no counts");
        e2.process(kv(1, 3), EVENT, &mut out);
        assert_eq!(out.0.last().unwrap().get(1).as_int(), Some(13));
    }

    #[test]
    fn snapshot_restore_roundtrip() {
        let mut e = Enrich::new();
        let mut out = VecEmitter::default();
        e.process(kv(2, 9), EVENT, &mut out); // early
        e.process(kv(1, 10), DICT, &mut out);
        let snap = e.snapshot();
        let mut e2 = Enrich::new();
        e2.restore(snap);
        assert!(!e2.dict_done);
        assert_eq!(e2.early.len(), 1);
        assert_eq!(e2.dict.len(), 1);
        e2.finish_port(DICT, &mut out);
        e2.finish(&mut out);
        let events: Vec<i64> = out
            .0
            .iter()
            .filter(|t| t.get(2).as_int() == Some(1))
            .map(|t| t.get(1).as_int().unwrap())
            .collect();
        assert_eq!(events, vec![9], "early event replayed post-restore");
    }

    #[test]
    fn split_by_hash_keeps_count_rows_with_their_shard() {
        let mut e = Enrich::new();
        let mut out = VecEmitter::default();
        run_dict(&mut e, &[], &mut out);
        for k in 0..20 {
            e.process(kv(k, 0), EVENT, &mut out);
        }
        let swept = e.partitioned_state();
        let shards = swept.split_by_hash(3);
        // Every tagged count row lands in the shard its key routes to.
        for (i, shard) in shards.iter().enumerate() {
            for (h, rows) in &shard.keyed_tuples {
                assert_eq!((*h % 3) as usize, i);
                for t in rows {
                    assert_eq!(t.get(1).stable_hash(), *h);
                }
            }
        }
    }
}

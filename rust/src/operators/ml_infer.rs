//! The ML operator: tweet-text classification through the AOT-compiled
//! JAX/Pallas model (L2/L1 of the three-layer stack).
//!
//! This is the role the paper's `SentimentAnalysis` operator plays in
//! workflow W3 (§2.7.5, an "expensive ML operator" based on the
//! CognitiveRocket package) and the `ML` operators of Ch. 4's climate
//! workflow. Tuples are micro-batched to the model's fixed batch shape;
//! the last partial batch is zero-padded. Tokenization is a
//! deterministic hash of whitespace-split words.
//!
//! The operator talks to the PJRT [`InferenceHandle`] (a dedicated
//! server thread owning the compiled executable); Python never runs on
//! this path.

use crate::engine::operator::{Emitter, Operator};
use crate::runtime::{InferenceHandle, Tensor};
use crate::tuple::{Tuple, TupleBatch, Value};

/// Model input batch size (must match python/compile/model.py).
pub const BATCH: usize = 32;
/// Tokens per example.
pub const TOKENS: usize = 16;
/// Vocabulary size.
pub const VOCAB: usize = 4096;
/// Output classes of the topic classifier.
pub const CLASSES: usize = 8;

/// Hash-tokenize a text into exactly `TOKENS` ids (0 = padding).
pub fn tokenize(text: &str) -> Vec<i32> {
    let mut ids = Vec::with_capacity(TOKENS);
    for w in text.split_whitespace().take(TOKENS) {
        let h = Value::str(w).stable_hash();
        ids.push((1 + (h % (VOCAB as u64 - 1))) as i32);
    }
    ids.resize(TOKENS, 0);
    ids
}

/// ML inference operator: appends the argmax class id to each tuple.
pub struct MlInfer {
    pub text_field: usize,
    pub model: String,
    handle: InferenceHandle,
    buffer: Vec<Tuple>,
    classes: usize,
}

impl MlInfer {
    pub fn new(text_field: usize, model: &str, handle: InferenceHandle) -> MlInfer {
        let classes = if model.starts_with("sentiment") { 2 } else { CLASSES };
        MlInfer {
            text_field,
            model: model.to_string(),
            handle,
            buffer: Vec::with_capacity(BATCH),
            classes,
        }
    }

    fn flush(&mut self, out: &mut dyn Emitter) {
        if self.buffer.is_empty() {
            return;
        }
        let n = self.buffer.len();
        let mut tokens = Vec::with_capacity(BATCH * TOKENS);
        for t in &self.buffer {
            let text = t.get(self.text_field).as_str().unwrap_or("");
            tokens.extend(tokenize(text));
        }
        // Zero-pad to the fixed batch shape.
        tokens.resize(BATCH * TOKENS, 0);
        let logits = self
            .handle
            .run(
                &self.model,
                vec![Tensor::I32(tokens, vec![BATCH as i64, TOKENS as i64])],
            )
            .expect("ML inference failed (are artifacts built?)");
        for (i, t) in self.buffer.drain(..).enumerate() {
            let row = &logits[i * self.classes..(i + 1) * self.classes];
            let class = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(c, _)| c as i64)
                .unwrap_or(0);
            let mut vals: Vec<Value> = t.values.to_vec();
            vals.push(Value::Int(class));
            out.emit(Tuple::new(vals));
        }
        debug_assert!(n <= BATCH);
    }
}

impl Operator for MlInfer {
    fn name(&self) -> &str {
        "ml_infer"
    }

    fn process(&mut self, t: Tuple, _port: usize, out: &mut dyn Emitter) {
        self.buffer.push(t);
        if self.buffer.len() >= BATCH {
            self.flush(out);
        }
    }

    /// Batched intake: an incoming chunk tops up the model's fixed
    /// `BATCH` shape directly, so a chunk of ≥ `BATCH` tuples triggers
    /// PJRT inference inline instead of one micro-flush per tuple.
    fn process_batch(&mut self, batch: &TupleBatch, _port: usize, out: &mut dyn Emitter) {
        self.buffer.reserve(batch.len().min(BATCH));
        for t in batch.iter() {
            self.buffer.push(t.clone());
            if self.buffer.len() >= BATCH {
                self.flush(out);
            }
        }
    }

    fn finish(&mut self, out: &mut dyn Emitter) {
        self.flush(out);
    }

    fn state_size(&self) -> usize {
        self.buffer.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokenize_is_deterministic_and_padded() {
        let a = tokenize("covid cases rising");
        let b = tokenize("covid cases rising");
        assert_eq!(a, b);
        assert_eq!(a.len(), TOKENS);
        assert_eq!(a[3], 0, "padding after 3 words");
        assert!(a[0] > 0, "real tokens are nonzero");
    }

    #[test]
    fn tokenize_distinguishes_words() {
        assert_ne!(tokenize("wildfire smoke"), tokenize("covid cases"));
    }

    #[test]
    fn tokenize_truncates_long_text() {
        let long = "w ".repeat(100);
        assert_eq!(tokenize(&long).len(), TOKENS);
    }

    /// Full operator test through PJRT; skipped without artifacts.
    #[test]
    fn classify_appends_class() {
        if !crate::runtime::pjrt::artifact_exists("artifacts", "classifier") {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let server = crate::runtime::InferenceServer::start("artifacts");
        let mut op = MlInfer::new(0, "classifier", server.handle());
        let mut out = crate::engine::operator::VecEmitter::default();
        for i in 0..(BATCH + 3) {
            op.process(
                Tuple::new(vec![Value::str(&format!("tweet number {i} about covid"))]),
                0,
                &mut out,
            );
        }
        op.finish(&mut out);
        assert_eq!(out.0.len(), BATCH + 3);
        for t in &out.0 {
            let class = t.get(1).as_int().unwrap();
            assert!((0..CLASSES as i64).contains(&class));
        }
        // Same text → same class (deterministic model).
        let mut out2 = crate::engine::operator::VecEmitter::default();
        op.process(Tuple::new(vec![Value::str("tweet number 0 about covid")]), 0, &mut out2);
        op.finish(&mut out2);
        assert_eq!(out2.0[0].get(1), out.0[0].get(1));
    }
}

//! Admission control: the bounded submission queue with per-tenant
//! quotas, priority bands, and round-robin fairness.
//!
//! A submission is **rejected** (structured [`AdmissionError`]) when
//! the global queue is full, the tenant's `max_queued` quota is spent,
//! or the workflow's minimum footprint (one worker per operator) can
//! never fit the global budget. An *accepted* submission is only ever
//! deferred — the serving layer keeps draining the queue as capacity
//! frees, so every admitted workflow eventually runs.
//!
//! Dispatch order: the Interactive band drains before the Batch band;
//! inside a band, tenants rotate round-robin (by `TenantId` order) and
//! each tenant's own jobs stay FIFO — so a chatty tenant cannot starve
//! a quiet one, and short interactive jobs overtake long batch scans
//! without cancelling them. `fifo: true` switches to priority-blind
//! arrival order (the bench baseline the priority policy is measured
//! against).

use crate::service::tenant::TenantId;
use crate::service::{JobId, Priority};
use std::collections::{HashMap, VecDeque};

/// Why a submission was turned away at the door.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum AdmissionError {
    /// The global submission queue is at `queue_cap`.
    QueueFull { cap: usize },
    /// The tenant already has `max_queued` submissions waiting.
    QuotaExceeded { tenant: TenantId, max_queued: usize },
    /// The workflow needs more workers than the whole budget even at
    /// one worker per operator — it could never start.
    TooLarge { min_workers: usize, capacity: usize },
    /// The service is shutting down.
    ShuttingDown,
}

impl std::fmt::Display for AdmissionError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AdmissionError::QueueFull { cap } => {
                write!(f, "submission queue full (cap {cap})")
            }
            AdmissionError::QuotaExceeded { tenant, max_queued } => {
                write!(f, "{tenant} already has {max_queued} queued submissions")
            }
            AdmissionError::TooLarge { min_workers, capacity } => write!(
                f,
                "workflow needs at least {min_workers} workers but the budget is {capacity}"
            ),
            AdmissionError::ShuttingDown => write!(f, "service is shutting down"),
        }
    }
}

impl std::error::Error for AdmissionError {}

/// A queued (admitted, not yet started) job.
#[derive(Clone, Debug)]
pub(crate) struct QueuedJob {
    pub id: JobId,
    pub tenant: TenantId,
    pub priority: Priority,
    /// One worker per operator — the smallest grant that can deploy it.
    pub min_workers: usize,
}

/// The bounded submission queue. Arrival order is preserved in one
/// deque; selection scans it per (band, tenant), so fairness never
/// reorders storage.
pub(crate) struct AdmissionQueue {
    cap: usize,
    fifo: bool,
    q: VecDeque<QueuedJob>,
    queued_by_tenant: HashMap<TenantId, usize>,
    /// Last tenant served per band, for round-robin rotation.
    last_served: [Option<TenantId>; 2],
}

impl AdmissionQueue {
    pub fn new(cap: usize, fifo: bool) -> AdmissionQueue {
        AdmissionQueue {
            cap,
            fifo,
            q: VecDeque::new(),
            queued_by_tenant: HashMap::new(),
            last_served: [None, None],
        }
    }

    pub fn len(&self) -> usize {
        self.q.len()
    }

    /// Admit or reject one submission.
    pub fn push(&mut self, job: QueuedJob, max_queued: usize) -> Result<(), AdmissionError> {
        if self.q.len() >= self.cap {
            return Err(AdmissionError::QueueFull { cap: self.cap });
        }
        let n = self.queued_by_tenant.entry(job.tenant).or_insert(0);
        if *n >= max_queued {
            return Err(AdmissionError::QuotaExceeded { tenant: job.tenant, max_queued });
        }
        *n += 1;
        self.q.push_back(job);
        Ok(())
    }

    /// Re-insert a job at the *front* after a failed start attempt
    /// (budget didn't fit) — it keeps its precedence within its band
    /// and tenant.
    pub fn push_front(&mut self, job: QueuedJob) {
        *self.queued_by_tenant.entry(job.tenant).or_insert(0) += 1;
        self.q.push_front(job);
    }

    /// Remove a specific queued job (cancellation).
    pub fn remove(&mut self, id: JobId) -> Option<QueuedJob> {
        let pos = self.q.iter().position(|j| j.id == id)?;
        let job = self.q.remove(pos).unwrap();
        self.dec(job.tenant);
        Some(job)
    }

    /// Pop the next job to try starting, among those `eligible` (the
    /// caller checks tenant run caps there). Priority mode: Interactive
    /// band first, round-robin across tenants within the band, FIFO
    /// within a tenant. FIFO mode: plain arrival order, priority-blind
    /// (ineligible jobs are skipped rather than wedging the queue —
    /// the baseline differs in *ordering*, not in quota semantics).
    pub fn take_next(
        &mut self,
        mut eligible: impl FnMut(&QueuedJob) -> bool,
    ) -> Option<QueuedJob> {
        if self.fifo {
            let pos = self.q.iter().position(|j| eligible(j))?;
            let job = self.q.remove(pos).unwrap();
            self.dec(job.tenant);
            return Some(job);
        }
        for band in [Priority::Interactive, Priority::Batch] {
            let mut tenants: Vec<TenantId> = self
                .q
                .iter()
                .filter(|j| j.priority == band && eligible(j))
                .map(|j| j.tenant)
                .collect();
            tenants.sort();
            tenants.dedup();
            if tenants.is_empty() {
                continue;
            }
            let pick = match self.last_served[band.band()] {
                Some(c) => tenants.iter().copied().find(|&t| t > c).unwrap_or(tenants[0]),
                None => tenants[0],
            };
            self.last_served[band.band()] = Some(pick);
            let pos = self
                .q
                .iter()
                .position(|j| j.priority == band && j.tenant == pick && eligible(j))
                .expect("tenant selected from live scan");
            let job = self.q.remove(pos).unwrap();
            self.dec(job.tenant);
            return Some(job);
        }
        None
    }

    /// Drain everything (service shutdown) — callers notify waiters.
    pub fn drain_all(&mut self) -> Vec<QueuedJob> {
        self.queued_by_tenant.clear();
        self.q.drain(..).collect()
    }

    fn dec(&mut self, tenant: TenantId) {
        if let Some(n) = self.queued_by_tenant.get_mut(&tenant) {
            *n = n.saturating_sub(1);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn job(id: u64, tenant: u64, pri: Priority) -> QueuedJob {
        QueuedJob {
            id: JobId(id),
            tenant: TenantId(tenant),
            priority: pri,
            min_workers: 1,
        }
    }

    #[test]
    fn rejects_when_full_or_over_quota() {
        let mut q = AdmissionQueue::new(2, false);
        assert!(q.push(job(1, 0, Priority::Batch), 1).is_ok());
        assert_eq!(
            q.push(job(2, 0, Priority::Batch), 1),
            Err(AdmissionError::QuotaExceeded { tenant: TenantId(0), max_queued: 1 })
        );
        assert!(q.push(job(3, 1, Priority::Batch), 1).is_ok());
        assert_eq!(
            q.push(job(4, 2, Priority::Batch), 1),
            Err(AdmissionError::QueueFull { cap: 2 })
        );
    }

    #[test]
    fn interactive_band_drains_first_with_tenant_rotation() {
        let mut q = AdmissionQueue::new(16, false);
        q.push(job(1, 0, Priority::Batch), 8).unwrap();
        q.push(job(2, 1, Priority::Interactive), 8).unwrap();
        q.push(job(3, 1, Priority::Interactive), 8).unwrap();
        q.push(job(4, 2, Priority::Interactive), 8).unwrap();
        // Interactive first; tenants rotate 1 → 2 → 1; batch last.
        assert_eq!(q.take_next(|_| true).unwrap().id, JobId(2));
        assert_eq!(q.take_next(|_| true).unwrap().id, JobId(4));
        assert_eq!(q.take_next(|_| true).unwrap().id, JobId(3));
        assert_eq!(q.take_next(|_| true).unwrap().id, JobId(1));
        assert!(q.take_next(|_| true).is_none());
    }

    #[test]
    fn fifo_mode_is_priority_blind() {
        let mut q = AdmissionQueue::new(16, true);
        q.push(job(1, 0, Priority::Batch), 8).unwrap();
        q.push(job(2, 1, Priority::Interactive), 8).unwrap();
        assert_eq!(q.take_next(|_| true).unwrap().id, JobId(1));
        assert_eq!(q.take_next(|_| true).unwrap().id, JobId(2));
    }

    #[test]
    fn push_front_restores_precedence() {
        let mut q = AdmissionQueue::new(16, false);
        q.push(job(1, 0, Priority::Batch), 8).unwrap();
        q.push(job(2, 0, Priority::Batch), 8).unwrap();
        let j = q.take_next(|_| true).unwrap();
        assert_eq!(j.id, JobId(1));
        q.push_front(j);
        assert_eq!(q.take_next(|_| true).unwrap().id, JobId(1));
    }
}

//! Structural plan fingerprints and the cross-workflow result cache.
//!
//! Two tenants running the *same* workflow shouldn't both pay for it —
//! the Texera service setting has heavy plan reuse (shared dashboards,
//! re-executed notebooks). [`plan_fingerprint`] hashes a workflow's
//! **structure**: operator names, port wiring, partitioning schemes
//! (including `Range` bounds), blocking ports and source/scatter-merge
//! flags — everything in the plan except worker counts (parallelism
//! does not change the result multiset) and the operator *closures*,
//! which cannot be hashed. Because closures are invisible, two plans
//! with identical structure but different captured constants would
//! collide; caching is therefore strictly **opt-in** per submission,
//! and the caller-supplied `salt` must encode whatever the closures
//! capture (predicate constants, scale factors, dataset version).
//!
//! [`ResultCache`] maps fingerprint → a [`MatStore`] holding the
//! completed job's sink rows — the same store the engine uses for
//! materialized links, reused across workflows. A hit returns the rows
//! without deploying a single worker.

use crate::engine::dag::Workflow;
use crate::engine::partitioner::PartitionScheme;
use crate::maestro::materialize::MatStore;
use crate::tuple::{mix64, Tuple};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Deterministic structural hash of a workflow plan, keyed by `salt`.
/// Stable across processes and runs (no addresses, no RandomState) —
/// built from `mix64` chaining like
/// [`Value::stable_hash`](crate::tuple::Value::stable_hash), which also
/// hashes any `Range` partition bounds.
pub fn plan_fingerprint(w: &Workflow, salt: u64) -> u64 {
    let mut h = mix64(salt ^ 0x9E37_79B9_7F4A_7C15);
    let mut fold = |h: &mut u64, v: u64| *h = mix64(*h ^ v);
    fold(&mut h, w.ops.len() as u64);
    for op in &w.ops {
        fold(&mut h, op.name.len() as u64);
        for b in op.name.bytes() {
            fold(&mut h, b as u64);
        }
        fold(&mut h, op.is_source as u64);
        fold(&mut h, op.scatter_merge as u64);
        fold(&mut h, op.blocking_ports.len() as u64);
        for &bp in &op.blocking_ports {
            fold(&mut h, bp as u64);
        }
        fold(&mut h, op.input_partitioning.len() as u64);
        for s in &op.input_partitioning {
            fold(&mut h, scheme_fingerprint(s));
        }
    }
    fold(&mut h, w.edges.len() as u64);
    for e in &w.edges {
        fold(&mut h, e.from as u64);
        fold(&mut h, e.to as u64);
        fold(&mut h, e.to_port as u64);
    }
    h
}

fn scheme_fingerprint(s: &PartitionScheme) -> u64 {
    match s {
        PartitionScheme::OneToOne => mix64(1),
        PartitionScheme::RoundRobin => mix64(2),
        PartitionScheme::Hash { key } => mix64(3 ^ ((*key as u64) << 8)),
        PartitionScheme::Range { key, bounds } => {
            let mut h = mix64(4 ^ ((*key as u64) << 8));
            for b in bounds {
                h = mix64(h ^ b.stable_hash());
            }
            h
        }
        PartitionScheme::Broadcast => mix64(5),
    }
}

/// Fingerprint-keyed store of completed sink-row sets, shared across
/// tenants. Entries are whole-result only — a job that failed, was
/// cancelled, or aborted never lands here.
#[derive(Default)]
pub struct ResultCache {
    entries: Mutex<HashMap<u64, MatStore>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl ResultCache {
    pub fn new() -> ResultCache {
        ResultCache::default()
    }

    /// Rows for `fp`, if cached. Counts a hit or a miss.
    pub fn lookup(&self, fp: u64) -> Option<Vec<Tuple>> {
        let entries = self.entries.lock().unwrap();
        match entries.get(&fp) {
            Some(store) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(store.snapshot())
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Store a completed job's sink rows under `fp` (first writer
    /// wins — concurrent identical runs insert identical rows anyway).
    pub fn insert(&self, fp: u64, rows: Vec<Tuple>) {
        let mut entries = self.entries.lock().unwrap();
        entries.entry(fp).or_default().append_rows(rows);
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    pub fn len(&self) -> usize {
        self.entries.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::dag::OpSpec;
    use crate::engine::operator::{Emitter, Operator};
    use crate::workloads::VecSource;

    struct Noop;
    impl Operator for Noop {
        fn name(&self) -> &str {
            "noop"
        }
        fn process(&mut self, t: Tuple, _p: usize, out: &mut dyn Emitter) {
            out.emit(t);
        }
    }

    fn flow(name: &str, workers: usize) -> Workflow {
        let mut w = Workflow::new();
        let s = w.add(OpSpec::source("scan", workers, |_, _| {
            Box::new(VecSource::new(Vec::new()))
        }));
        let k = w.add(OpSpec::unary(name, workers, PartitionScheme::Hash { key: 0 }, |_, _| {
            Box::new(Noop)
        }));
        w.connect(s, k, 0);
        w
    }

    #[test]
    fn fingerprint_stable_and_structure_sensitive() {
        assert_eq!(
            plan_fingerprint(&flow("sink", 1), 7),
            plan_fingerprint(&flow("sink", 1), 7)
        );
        // Worker counts are excluded: a scaled plan reuses the cache.
        assert_eq!(
            plan_fingerprint(&flow("sink", 1), 7),
            plan_fingerprint(&flow("sink", 4), 7)
        );
        // Names, salts, and schemes all matter.
        assert_ne!(
            plan_fingerprint(&flow("sink", 1), 7),
            plan_fingerprint(&flow("other", 1), 7)
        );
        assert_ne!(
            plan_fingerprint(&flow("sink", 1), 7),
            plan_fingerprint(&flow("sink", 1), 8)
        );
    }

    #[test]
    fn cache_round_trip_counts_hits() {
        let c = ResultCache::new();
        assert!(c.lookup(42).is_none());
        c.insert(42, vec![Tuple::new(vec![crate::tuple::Value::Int(9)])]);
        let rows = c.lookup(42).expect("hit");
        assert_eq!(rows.len(), 1);
        // Snapshot, not drain: a second hit sees the same rows.
        assert_eq!(c.lookup(42).unwrap().len(), 1);
        assert_eq!(c.hits(), 2);
        assert_eq!(c.misses(), 1);
    }
}

//! Structural plan fingerprints and the cross-workflow result cache.
//!
//! Two tenants running the *same* workflow shouldn't both pay for it —
//! the Texera service setting has heavy plan reuse (shared dashboards,
//! re-executed notebooks). [`plan_fingerprint`] hashes a workflow's
//! **structure**: operator names, port wiring, partitioning schemes
//! (including `Range` bounds), blocking ports and source/scatter-merge
//! flags — everything in the plan except worker counts (parallelism
//! does not change the result multiset) and the operator *closures*,
//! which cannot be hashed. Because closures are invisible, two plans
//! with identical structure but different captured constants would
//! collide; caching is therefore strictly **opt-in** per submission,
//! and the caller-supplied `salt` must encode whatever the closures
//! capture (predicate constants, scale factors, dataset version).
//!
//! [`ResultCache`] maps fingerprint → a [`MatStore`] holding the
//! completed job's sink rows — the same store the engine uses for
//! materialized links, reused across workflows. A hit returns the rows
//! without deploying a single worker. The cache is bounded (entry and
//! byte caps, least-recently-used eviction) so a long-running service
//! does not grow without bound per distinct plan.

use crate::engine::dag::Workflow;
use crate::engine::partitioner::PartitionScheme;
use crate::maestro::materialize::MatStore;
use crate::tuple::{mix64, Tuple};
use std::collections::hash_map::Entry;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Deterministic structural hash of a workflow plan, keyed by `salt`.
/// Stable across processes and runs (no addresses, no RandomState) —
/// built from `mix64` chaining like
/// [`Value::stable_hash`](crate::tuple::Value::stable_hash), which also
/// hashes any `Range` partition bounds.
pub fn plan_fingerprint(w: &Workflow, salt: u64) -> u64 {
    let mut h = mix64(salt ^ 0x9E37_79B9_7F4A_7C15);
    let mut fold = |h: &mut u64, v: u64| *h = mix64(*h ^ v);
    fold(&mut h, w.ops.len() as u64);
    for op in &w.ops {
        fold(&mut h, op.name.len() as u64);
        for b in op.name.bytes() {
            fold(&mut h, b as u64);
        }
        fold(&mut h, op.is_source as u64);
        fold(&mut h, op.scatter_merge as u64);
        fold(&mut h, op.blocking_ports.len() as u64);
        for &bp in &op.blocking_ports {
            fold(&mut h, bp as u64);
        }
        fold(&mut h, op.input_partitioning.len() as u64);
        for s in &op.input_partitioning {
            fold(&mut h, scheme_fingerprint(s));
        }
    }
    fold(&mut h, w.edges.len() as u64);
    for e in &w.edges {
        fold(&mut h, e.from as u64);
        fold(&mut h, e.to as u64);
        fold(&mut h, e.to_port as u64);
    }
    h
}

fn scheme_fingerprint(s: &PartitionScheme) -> u64 {
    match s {
        PartitionScheme::OneToOne => mix64(1),
        PartitionScheme::RoundRobin => mix64(2),
        PartitionScheme::Hash { key } => mix64(3 ^ ((*key as u64) << 8)),
        PartitionScheme::Range { key, bounds } => {
            let mut h = mix64(4 ^ ((*key as u64) << 8));
            for b in bounds {
                h = mix64(h ^ b.stable_hash());
            }
            h
        }
        PartitionScheme::Broadcast => mix64(5),
    }
}

/// Default [`ResultCache`] entry cap.
pub const DEFAULT_CACHE_ENTRIES: usize = 1024;
/// Default [`ResultCache`] byte cap (64 MiB of cached sink rows).
pub const DEFAULT_CACHE_BYTES: u64 = 64 << 20;

struct CacheEntry {
    store: MatStore,
    bytes: u64,
    last_used: u64,
}

struct CacheInner {
    map: HashMap<u64, CacheEntry>,
    bytes: u64,
    tick: u64,
}

/// Fingerprint-keyed store of completed sink-row sets, shared across
/// tenants. Entries are whole-result only — a job that failed, was
/// cancelled, or aborted never lands here — and immutable once
/// written: [`insert`](Self::insert) is strictly first-writer-wins.
/// Bounded by an entry cap and a byte cap (0 = unbounded); when either
/// overflows, the least-recently-used entries are evicted.
pub struct ResultCache {
    inner: Mutex<CacheInner>,
    max_entries: usize,
    max_bytes: u64,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
}

impl Default for ResultCache {
    fn default() -> ResultCache {
        ResultCache::with_limits(DEFAULT_CACHE_ENTRIES, DEFAULT_CACHE_BYTES)
    }
}

impl ResultCache {
    pub fn new() -> ResultCache {
        ResultCache::default()
    }

    /// A cache bounded to `max_entries` entries and `max_bytes` bytes
    /// of sink rows (0 = unbounded for either).
    pub fn with_limits(max_entries: usize, max_bytes: u64) -> ResultCache {
        ResultCache {
            inner: Mutex::new(CacheInner { map: HashMap::new(), bytes: 0, tick: 0 }),
            max_entries,
            max_bytes,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    /// Rows for `fp`, if cached. Counts a hit or a miss; a hit
    /// refreshes the entry's eviction age.
    pub fn lookup(&self, fp: u64) -> Option<Vec<Tuple>> {
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        match inner.map.get_mut(&fp) {
            Some(entry) => {
                entry.last_used = tick;
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(entry.store.snapshot())
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Store a completed job's sink rows under `fp`. Strictly first
    /// writer wins: an occupied entry is left untouched (two identical
    /// cold runs completing concurrently must not double the rows).
    /// Rows larger than the whole byte cap are not cached at all.
    pub fn insert(&self, fp: u64, rows: Vec<Tuple>) {
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        let Entry::Vacant(slot) = inner.map.entry(fp) else { return };
        let store = MatStore::new();
        store.append_rows(rows);
        let bytes = store.bytes();
        if self.max_bytes > 0 && bytes > self.max_bytes {
            return;
        }
        slot.insert(CacheEntry { store, bytes, last_used: tick });
        inner.bytes += bytes;
        // Evict least-recently-used entries until within bounds; the
        // just-inserted entry carries the freshest tick and survives.
        while (self.max_entries > 0 && inner.map.len() > self.max_entries)
            || (self.max_bytes > 0 && inner.bytes > self.max_bytes)
        {
            let Some(&oldest) = inner
                .map
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(fp, _)| fp)
            else {
                break;
            };
            if let Some(evicted) = inner.map.remove(&oldest) {
                inner.bytes -= evicted.bytes;
                self.evictions.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Entries dropped to keep the cache within its bounds.
    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }

    /// Bytes of sink rows currently held.
    pub fn bytes(&self) -> u64 {
        self.inner.lock().unwrap().bytes
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::dag::OpSpec;
    use crate::engine::operator::{Emitter, Operator};
    use crate::workloads::VecSource;

    struct Noop;
    impl Operator for Noop {
        fn name(&self) -> &str {
            "noop"
        }
        fn process(&mut self, t: Tuple, _p: usize, out: &mut dyn Emitter) {
            out.emit(t);
        }
    }

    fn flow(name: &str, workers: usize) -> Workflow {
        let mut w = Workflow::new();
        let s = w.add(OpSpec::source("scan", workers, |_, _| {
            Box::new(VecSource::new(Vec::new()))
        }));
        let k = w.add(OpSpec::unary(name, workers, PartitionScheme::Hash { key: 0 }, |_, _| {
            Box::new(Noop)
        }));
        w.connect(s, k, 0);
        w
    }

    #[test]
    fn fingerprint_stable_and_structure_sensitive() {
        assert_eq!(
            plan_fingerprint(&flow("sink", 1), 7),
            plan_fingerprint(&flow("sink", 1), 7)
        );
        // Worker counts are excluded: a scaled plan reuses the cache.
        assert_eq!(
            plan_fingerprint(&flow("sink", 1), 7),
            plan_fingerprint(&flow("sink", 4), 7)
        );
        // Names, salts, and schemes all matter.
        assert_ne!(
            plan_fingerprint(&flow("sink", 1), 7),
            plan_fingerprint(&flow("other", 1), 7)
        );
        assert_ne!(
            plan_fingerprint(&flow("sink", 1), 7),
            plan_fingerprint(&flow("sink", 1), 8)
        );
    }

    #[test]
    fn cache_round_trip_counts_hits() {
        let c = ResultCache::new();
        assert!(c.lookup(42).is_none());
        c.insert(42, vec![Tuple::new(vec![crate::tuple::Value::Int(9)])]);
        let rows = c.lookup(42).expect("hit");
        assert_eq!(rows.len(), 1);
        // Snapshot, not drain: a second hit sees the same rows.
        assert_eq!(c.lookup(42).unwrap().len(), 1);
        assert_eq!(c.hits(), 2);
        assert_eq!(c.misses(), 1);
    }

    #[test]
    fn cache_insert_is_first_writer_wins() {
        let c = ResultCache::new();
        let row = || Tuple::new(vec![crate::tuple::Value::Int(9)]);
        c.insert(42, vec![row()]);
        // A second identical cold run completing concurrently must not
        // double the entry's rows.
        c.insert(42, vec![row()]);
        assert_eq!(c.lookup(42).expect("hit").len(), 1, "occupied entry must stay untouched");
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn cache_evicts_least_recently_used_past_entry_cap() {
        let c = ResultCache::with_limits(2, 0);
        let row = || Tuple::new(vec![crate::tuple::Value::Int(1)]);
        c.insert(1, vec![row()]);
        c.insert(2, vec![row()]);
        // Touch 1 so 2 is the LRU when 3 overflows the cap.
        assert!(c.lookup(1).is_some());
        c.insert(3, vec![row()]);
        assert_eq!(c.len(), 2);
        assert_eq!(c.evictions(), 1);
        assert!(c.lookup(2).is_none(), "LRU entry must be evicted");
        assert!(c.lookup(1).is_some());
        assert!(c.lookup(3).is_some());
    }

    #[test]
    fn cache_byte_cap_bounds_and_rejects_oversize() {
        let row = || Tuple::new(vec![crate::tuple::Value::Int(1)]);
        let probe = ResultCache::with_limits(0, 0);
        probe.insert(0, vec![row()]);
        let per_entry = probe.bytes();
        assert!(per_entry > 0);

        // Cap fits exactly two entries: a third insert evicts the LRU.
        let c = ResultCache::with_limits(0, 2 * per_entry);
        c.insert(1, vec![row()]);
        c.insert(2, vec![row()]);
        c.insert(3, vec![row()]);
        assert_eq!(c.len(), 2);
        assert!(c.bytes() <= 2 * per_entry);
        assert!(c.lookup(1).is_none(), "oldest entry evicted by byte cap");

        // A result bigger than the whole cap is not cached at all.
        let tiny = ResultCache::with_limits(0, 1);
        tiny.insert(9, vec![row()]);
        assert!(tiny.is_empty());
    }
}

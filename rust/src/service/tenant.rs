//! Tenant identity and per-tenant quotas.
//!
//! A tenant is one user (or one API key, one notebook — the unit the
//! Texera service bills and isolates). The serving layer tracks, per
//! tenant, how many submissions sit in the admission queue, how many
//! jobs run, and how many workers of the global budget it holds; the
//! [`TenantQuota`] caps each of those so one tenant can neither flood
//! the queue nor monopolize the worker pool.

/// Opaque tenant identity. Ordering is used only for deterministic
/// round-robin rotation inside the admission queue.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct TenantId(pub u64);

impl std::fmt::Display for TenantId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "tenant-{}", self.0)
    }
}

/// Per-tenant admission limits. Applied by the serving layer at submit
/// time (`max_queued` — exceeding it *rejects* the submission) and at
/// start time (`max_running`, `max_worker_share` — exceeding those
/// merely defers the job in the queue).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TenantQuota {
    /// Submissions this tenant may have waiting in the admission queue.
    pub max_queued: usize,
    /// Jobs this tenant may have running (or preempted-but-live) at
    /// once.
    pub max_running: usize,
    /// Fraction of the global worker budget this tenant may hold at
    /// once (1.0 = no per-tenant cap). Ignored when the budget is
    /// unbounded (`capacity == 0`).
    pub max_worker_share: f64,
    /// Fraction of the service's memory budget
    /// (`Config::memory_budget_bytes`) each of this tenant's jobs may
    /// keep resident before its operators spill (1.0 = the full
    /// budget). Ignored when the service budget is unbounded — a
    /// service that never spills doesn't start just because a tenant
    /// is throttled.
    pub max_memory_share: f64,
}

impl Default for TenantQuota {
    fn default() -> TenantQuota {
        TenantQuota {
            max_queued: 64,
            max_running: 8,
            max_worker_share: 1.0,
            max_memory_share: 1.0,
        }
    }
}

impl TenantQuota {
    /// Workers this quota allows the tenant to hold out of `capacity`
    /// (0 = unbounded budget → no cap). At least 1 when capped, so a
    /// tiny share on a tiny cluster cannot starve the tenant outright.
    pub fn worker_allowance(&self, capacity: usize) -> usize {
        if capacity == 0 {
            usize::MAX
        } else {
            ((self.max_worker_share * capacity as f64).floor() as usize).max(1)
        }
    }

    /// Memory budget one of this tenant's jobs gets out of the
    /// service-wide `budget_bytes` (0 = unbounded → stays unbounded).
    /// At least 1 byte when capped so the share can throttle but never
    /// silently turn a bounded service back into an unbounded one.
    pub fn memory_allowance(&self, budget_bytes: u64) -> u64 {
        if budget_bytes == 0 {
            0
        } else {
            ((self.max_memory_share * budget_bytes as f64).floor() as u64).max(1)
        }
    }
}

/// Live admission-side bookkeeping for one tenant.
#[derive(Clone, Debug, Default)]
pub(crate) struct TenantState {
    pub quota: TenantQuota,
    /// Jobs currently running or preempted (counted against
    /// `max_running` — a preempted job still owns engine state).
    pub running: usize,
}

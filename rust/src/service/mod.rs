//! The multi-tenant serving layer: many concurrent workflows on one
//! shared engine.
//!
//! The dissertation's setting (Texera) is a *service* — many users run
//! workflows simultaneously — yet `Execution` runs exactly one
//! workflow. [`EngineService`] closes that gap: it **admits** workflow
//! submissions through a bounded queue with per-tenant quotas
//! ([`admission`]), **arbitrates** [`Config::max_workers`] as a single
//! *global* worker budget across all tenants by generalizing Maestro's
//! greedy marginal-gain allocator from regions to workflows
//! ([`arbiter`]), runs each admitted job as its own [`Execution`], and
//! **reuses results** across tenants through a plan-fingerprint cache
//! ([`fingerprint`]).
//!
//! Lifecycle of one submission:
//!
//! 1. **Submit** — [`EngineService::submit`] hands a [`Submission`] to
//!    the service loop. A fingerprint-cache hit completes instantly;
//!    otherwise admission control either rejects (queue full, tenant
//!    over `max_queued`, plan larger than the whole budget) or
//!    enqueues.
//! 2. **Admission → arbitration** — when budget frees, the queue
//!    dispatches Interactive-band jobs first, rotating round-robin
//!    across tenants inside a band. The arbiter allocates the job's
//!    worker counts from the *remaining* global budget (running jobs
//!    keep their grants — allocation is incremental and
//!    work-conserving), charges the [`WorkerLedger`], and deploys.
//! 3. **Preemption** — an Interactive job that cannot fit first
//!    scale-downs running Batch jobs to one worker per operator
//!    (through the engine's fenced [`Execution::scale_operator`]),
//!    then pause-fences whole Batch jobs, **releasing their ledger
//!    grants while their threads stay parked** — the budget counts
//!    *runnable* workers (Whiz's decoupling of work allocation from a
//!    job's compute). Preempted jobs resume, grant re-acquired, as
//!    capacity frees.
//! 4. **Completion** — a per-job waiter thread turns
//!    [`Execution::on_done`] into a service-loop message: the grant is
//!    released, sink rows are collected (and cached when the
//!    submission opted in *and* carried a result sink), waiters are
//!    fulfilled, and the queue drains again. Results are
//!    deliver-once: collecting one evicts the job's entry, so neither
//!    the jobs map nor the (entry/byte-bounded) result cache grows
//!    without bound over a service's lifetime.
//!
//! Isolation: each job is its own `Execution` (own coordinator, own
//! workers, own channels), so a panicking or quota-exhausted tenant
//! cannot stall or corrupt another — composed with the supervision
//! layer, a crash either recovers in place (`ft_log` on) or aborts
//! just that job with a structured error. Pinned down by
//! `tests/service_isolation.rs` and the `CHAOS_SERVICE` fuzzer in
//! `tests/properties.rs`.
//!
//! [`Config::max_workers`]: crate::config::Config::max_workers

pub mod admission;
pub mod arbiter;
pub mod fingerprint;
pub mod tenant;

pub use admission::AdmissionError;
pub use arbiter::{arbitrate, ArbiterJob, WorkerLedger};
pub use fingerprint::{plan_fingerprint, ResultCache};
pub use tenant::{TenantId, TenantQuota};

use crate::config::Config;
use crate::engine::controller::{ExecSummary, Execution};
use crate::engine::dag::Workflow;
use crate::engine::fault::ExecError;
use crate::engine::migrate::PlanDelta;
use crate::maestro::cost::CostParams;
use crate::metrics::ServiceStats;
use crate::operators::SinkHandle;
use crate::service::admission::{AdmissionQueue, QueuedJob};
use crate::service::tenant::TenantState;
use crate::tuple::Tuple;
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Scheduling class of a submission. `Interactive` jobs dispatch ahead
/// of `Batch` jobs, bid with a higher arbitration weight, and may
/// preempt running Batch jobs when the budget is full.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Priority {
    Interactive,
    Batch,
}

impl Priority {
    /// Band index: Interactive drains before Batch.
    pub(crate) fn band(self) -> usize {
        match self {
            Priority::Interactive => 0,
            Priority::Batch => 1,
        }
    }
}

/// Service-wide job identity, unique for the service's lifetime.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct JobId(pub u64);

/// One workflow submission.
pub struct Submission {
    pub tenant: TenantId,
    pub workflow: Workflow,
    pub priority: Priority,
    /// Sink handle whose captured tuples become
    /// [`WorkflowResult::rows`]. Without one, the job still runs but
    /// returns no rows (and is never cached).
    pub result_sink: Option<SinkHandle>,
    /// Opt-in result caching: the salt must encode everything the
    /// operator closures capture (predicate constants, dataset
    /// version) — the structural fingerprint cannot see inside them.
    /// Only a submission with a [`result_sink`](Self::result_sink)
    /// *populates* the cache (sink rows are what gets stored); a
    /// sink-less cacheable submission can still be served from it.
    pub cache_salt: Option<u64>,
    /// Per-job engine config override (fault plans, batch size). The
    /// service's global budget always comes from its own config, never
    /// from here.
    pub config: Option<Config>,
    /// Cost-model override for arbitration; defaults to the service's
    /// model seeded with each source's `len_hint`.
    pub cost: Option<CostParams>,
}

impl Submission {
    pub fn new(tenant: TenantId, workflow: Workflow) -> Submission {
        Submission {
            tenant,
            workflow,
            priority: Priority::Batch,
            result_sink: None,
            cache_salt: None,
            config: None,
            cost: None,
        }
    }

    pub fn interactive(mut self) -> Submission {
        self.priority = Priority::Interactive;
        self
    }

    pub fn with_sink(mut self, sink: SinkHandle) -> Submission {
        self.result_sink = Some(sink);
        self
    }

    pub fn cacheable(mut self, salt: u64) -> Submission {
        self.cache_salt = Some(salt);
        self
    }

    pub fn with_config(mut self, config: Config) -> Submission {
        self.config = Some(config);
        self
    }

    pub fn with_cost(mut self, cost: CostParams) -> Submission {
        self.cost = Some(cost);
        self
    }
}

/// Terminal outcome of one submission.
#[derive(Clone, Debug)]
pub struct WorkflowResult {
    pub id: JobId,
    pub tenant: TenantId,
    /// The submission's sink rows (from the result cache on a hit).
    pub rows: Vec<Tuple>,
    /// Structured engine error (unsupervised worker failure, recovery
    /// exhausted). `None` for clean completions and cancellations.
    pub error: Option<ExecError>,
    /// Cancelled by the caller or by service shutdown.
    pub cancelled: bool,
    /// Served from the plan-fingerprint cache without executing.
    pub cache_hit: bool,
    /// Seconds spent queued before deployment.
    pub queued_s: f64,
    /// Seconds from submission to this result.
    pub total_s: f64,
    /// Seconds from submission to the job's first sink output — the
    /// serving-layer `measured_frt` (queue wait included, so admission
    /// policy shows up here). `None` when the sink never reported.
    pub measured_frt: Option<f64>,
    /// Workers granted at deployment.
    pub workers_granted: usize,
    /// Times the job was pause-preempted for an interactive tenant.
    pub preemptions: u32,
}

/// Serving-layer configuration.
#[derive(Clone)]
pub struct ServiceConfig {
    /// Base engine config for every job; `engine.max_workers` is the
    /// **global** worker budget across all tenants (0 = unbounded).
    pub engine: Config,
    /// Bounded submission-queue capacity.
    pub queue_cap: usize,
    /// Quota applied to tenants without an explicit override.
    pub default_quota: TenantQuota,
    /// Per-tenant quota overrides.
    pub quotas: HashMap<u64, TenantQuota>,
    /// Priority-blind arrival-order admission with preemption disabled
    /// — the baseline the priority policy is benchmarked against.
    pub fifo: bool,
    /// Arbitration weight multiplying Interactive jobs' modeled work.
    pub interactive_weight: f64,
}

impl Default for ServiceConfig {
    fn default() -> ServiceConfig {
        ServiceConfig {
            engine: Config::default(),
            queue_cap: 256,
            default_quota: TenantQuota::default(),
            quotas: HashMap::new(),
            fifo: false,
            interactive_weight: 4.0,
        }
    }
}

impl ServiceConfig {
    /// Small queues and batches for tests.
    pub fn for_tests() -> ServiceConfig {
        ServiceConfig {
            engine: Config::for_tests(),
            queue_cap: 64,
            ..ServiceConfig::default()
        }
    }

    fn quota_of(&self, tenant: TenantId) -> TenantQuota {
        self.quotas.get(&tenant.0).copied().unwrap_or(self.default_quota)
    }
}

enum Msg {
    Submit {
        sub: Box<Submission>,
        reply: Sender<Result<JobId, AdmissionError>>,
    },
    Await {
        id: JobId,
        reply: Sender<Option<WorkflowResult>>,
    },
    Cancel {
        id: JobId,
        reply: Sender<bool>,
    },
    PauseJob {
        id: JobId,
        reply: Sender<bool>,
    },
    ResumeJob {
        id: JobId,
        reply: Sender<bool>,
    },
    ScaleJob {
        id: JobId,
        op: usize,
        workers: usize,
        reply: Sender<bool>,
    },
    MigrateJob {
        id: JobId,
        delta: PlanDelta,
        reply: Sender<bool>,
    },
    JobFinished {
        id: JobId,
        summary: Option<Box<ExecSummary>>,
    },
    Stats {
        reply: Sender<ServiceStats>,
    },
    Shutdown,
}

/// The shared multi-tenant engine frontend. One service loop thread
/// owns every live [`Execution`]; the public API exchanges messages
/// with it, so all admission, arbitration and preemption decisions are
/// serialized (the ledger's never-exceeded invariant has a single
/// writer for grants).
pub struct EngineService {
    tx: Sender<Msg>,
    loop_thread: Option<JoinHandle<()>>,
    ledger: Arc<WorkerLedger>,
    cache: Arc<ResultCache>,
    live_jobs: Arc<AtomicUsize>,
}

impl EngineService {
    /// Spin up the service loop. The global worker budget is
    /// `cfg.engine.max_workers` (0 = unbounded).
    pub fn start(cfg: ServiceConfig) -> EngineService {
        let (tx, rx) = channel();
        let ledger = Arc::new(WorkerLedger::new(cfg.engine.max_workers));
        let cache = Arc::new(ResultCache::new());
        let live_jobs = Arc::new(AtomicUsize::new(0));
        let loop_tx = tx.clone();
        let (ledger2, cache2, live2) = (ledger.clone(), cache.clone(), live_jobs.clone());
        let loop_thread = std::thread::Builder::new()
            .name("engine-service".into())
            .spawn(move || ServiceLoop::new(cfg, rx, loop_tx, ledger2, cache2, live2).run())
            .expect("spawn service loop");
        EngineService { tx, loop_thread: Some(loop_thread), ledger, cache, live_jobs }
    }

    /// Admit one workflow. `Ok(id)` means the job will run (or was
    /// served from cache) — await it with [`wait`](Self::wait).
    pub fn submit(&self, sub: Submission) -> Result<JobId, AdmissionError> {
        let (reply, rx) = channel();
        if self.tx.send(Msg::Submit { sub: Box::new(sub), reply }).is_err() {
            return Err(AdmissionError::ShuttingDown);
        }
        rx.recv().unwrap_or(Err(AdmissionError::ShuttingDown))
    }

    /// Block until job `id` reaches a terminal state; `None` for an
    /// unknown id. Results are delivered **once**: collecting a
    /// terminal result evicts the job (rows included) from the
    /// service, so a second wait on the same id returns `None`.
    pub fn wait(&self, id: JobId) -> Option<WorkflowResult> {
        let (reply, rx) = channel();
        self.tx.send(Msg::Await { id, reply }).ok()?;
        rx.recv().ok().flatten()
    }

    /// Submit + wait.
    pub fn run(&self, sub: Submission) -> Result<WorkflowResult, AdmissionError> {
        let id = self.submit(sub)?;
        Ok(self.wait(id).expect("submitted job must reach a terminal state"))
    }

    /// Cancel a queued or running job. False once it already finished.
    pub fn cancel(&self, id: JobId) -> bool {
        self.ask(|reply| Msg::Cancel { id, reply })
    }

    /// Pause a running job (the caller's grant is *held* — this is a
    /// user pause, not a preemption).
    pub fn pause_job(&self, id: JobId) -> bool {
        self.ask(|reply| Msg::PauseJob { id, reply })
    }

    /// Resume a job paused with [`pause_job`](Self::pause_job).
    /// Preempted jobs are service-managed and refuse a caller resume.
    pub fn resume_job(&self, id: JobId) -> bool {
        self.ask(|reply| Msg::ResumeJob { id, reply })
    }

    /// Scale one operator of a running job; a scale-up must fit the
    /// global budget, a scale-down returns workers to it.
    pub fn scale_job(&self, id: JobId, op: usize, workers: usize) -> bool {
        self.ask(|reply| Msg::ScaleJob { id, op, workers, reply })
    }

    /// Apply a live plan migration to a running job. Only deltas that
    /// keep the operator set intact are accepted (`Repartition`,
    /// `Replan` — a `Replan` settles the ledger exactly);
    /// materialization splicing changes the op set mid-flight and is
    /// refused at this layer.
    pub fn migrate_job(&self, id: JobId, delta: PlanDelta) -> bool {
        self.ask(|reply| Msg::MigrateJob { id, delta, reply })
    }

    /// Snapshot of the serving-layer counters.
    pub fn stats(&self) -> ServiceStats {
        let (reply, rx) = channel();
        if self.tx.send(Msg::Stats { reply }).is_err() {
            return ServiceStats::default();
        }
        rx.recv().unwrap_or_default()
    }

    /// The global budget ledger (tests assert on `peak()`).
    pub fn ledger(&self) -> &WorkerLedger {
        &self.ledger
    }

    /// The cross-workflow result cache.
    pub fn cache(&self) -> &ResultCache {
        &self.cache
    }

    /// Jobs admitted but not yet terminal (queued + running).
    pub fn live_jobs(&self) -> usize {
        self.live_jobs.load(Ordering::Relaxed)
    }

    fn ask(&self, make: impl FnOnce(Sender<bool>) -> Msg) -> bool {
        let (reply, rx) = channel();
        if self.tx.send(make(reply)).is_err() {
            return false;
        }
        rx.recv().unwrap_or(false)
    }
}

impl Drop for EngineService {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.loop_thread.take() {
            let _ = h.join();
        }
    }
}

/// A queued job's deployment ingredients, held until start.
struct PendingJob {
    workflow: Workflow,
    config: Config,
    cost: CostParams,
    sink: Option<SinkHandle>,
    sink_ops: Vec<usize>,
    fingerprint: Option<u64>,
    submitted_at: Instant,
}

struct RunningJob {
    exec: Execution,
    /// Current per-op worker counts (arbitration grant, updated by
    /// scale/migrate/preemption).
    counts: Vec<usize>,
    /// Workers currently charged to the ledger (0 while preempted).
    granted: usize,
    granted_at_start: usize,
    sink: Option<SinkHandle>,
    sink_ops: Vec<usize>,
    fingerprint: Option<u64>,
    submitted_at: Instant,
    started_at: Instant,
    /// Pause-fenced by the service with the grant released.
    preempted: bool,
    /// Paused by the caller with the grant held.
    user_paused: bool,
    preemptions: u32,
}

enum JobState {
    Queued,
    Running(RunningJob),
    Finished(WorkflowResult),
}

/// Outcome of one preempted-job resume attempt — the drain sweep
/// rotates past a tenant-capped job but stops on a full budget.
enum Resume {
    /// Resumed (or the job is gone): drop it from the preempted queue.
    Done,
    /// Blocked only by its own tenant's worker allowance.
    TenantCapped,
    /// Blocked by the global budget.
    BudgetFull,
}

struct Job {
    tenant: TenantId,
    priority: Priority,
    state: JobState,
    waiters: Vec<Sender<Option<WorkflowResult>>>,
}

struct ServiceLoop {
    cfg: ServiceConfig,
    rx: Receiver<Msg>,
    tx: Sender<Msg>,
    ledger: Arc<WorkerLedger>,
    cache: Arc<ResultCache>,
    live_jobs: Arc<AtomicUsize>,
    queue: AdmissionQueue,
    pending: HashMap<JobId, PendingJob>,
    jobs: HashMap<JobId, Job>,
    tenants: HashMap<TenantId, TenantState>,
    /// Preempted job ids, oldest first — resume order.
    preempted: VecDeque<JobId>,
    stats: ServiceStats,
    next_id: u64,
}

impl ServiceLoop {
    fn new(
        cfg: ServiceConfig,
        rx: Receiver<Msg>,
        tx: Sender<Msg>,
        ledger: Arc<WorkerLedger>,
        cache: Arc<ResultCache>,
        live_jobs: Arc<AtomicUsize>,
    ) -> ServiceLoop {
        let queue = AdmissionQueue::new(cfg.queue_cap, cfg.fifo);
        ServiceLoop {
            cfg,
            rx,
            tx,
            ledger,
            cache,
            live_jobs,
            queue,
            pending: HashMap::new(),
            jobs: HashMap::new(),
            tenants: HashMap::new(),
            preempted: VecDeque::new(),
            stats: ServiceStats::default(),
            next_id: 0,
        }
    }

    fn run(mut self) {
        loop {
            match self.rx.recv() {
                Ok(Msg::Submit { sub, reply }) => {
                    let _ = reply.send(self.submit(*sub));
                    self.drain();
                }
                Ok(Msg::Await { id, reply }) => {
                    // Deliver-once: handing out a terminal result also
                    // evicts the job (and its row vector) from the
                    // map, so a long-running service does not retain
                    // every result ever produced.
                    let finished = matches!(
                        self.jobs.get(&id).map(|j| &j.state),
                        Some(JobState::Finished(_))
                    );
                    if finished {
                        if let Some(job) = self.jobs.remove(&id) {
                            if let JobState::Finished(res) = job.state {
                                let _ = reply.send(Some(res));
                            }
                        }
                    } else {
                        match self.jobs.get_mut(&id) {
                            Some(job) => job.waiters.push(reply),
                            None => {
                                let _ = reply.send(None);
                            }
                        }
                    }
                }
                Ok(Msg::Cancel { id, reply }) => {
                    let _ = reply.send(self.cancel(id));
                    self.drain();
                }
                Ok(Msg::PauseJob { id, reply }) => {
                    let _ = reply.send(self.pause_job(id));
                }
                Ok(Msg::ResumeJob { id, reply }) => {
                    let _ = reply.send(self.resume_job(id));
                }
                Ok(Msg::ScaleJob { id, op, workers, reply }) => {
                    let _ = reply.send(self.scale_job(id, op, workers));
                    self.drain();
                }
                Ok(Msg::MigrateJob { id, delta, reply }) => {
                    let _ = reply.send(self.migrate_job(id, delta));
                    self.drain();
                }
                Ok(Msg::JobFinished { id, summary }) => {
                    self.finish(id, summary.map(|b| *b));
                    self.drain();
                }
                Ok(Msg::Stats { reply }) => {
                    let _ = reply.send(self.snapshot());
                }
                Ok(Msg::Shutdown) | Err(_) => {
                    self.shutdown();
                    return;
                }
            }
        }
    }

    // ---- submission ---------------------------------------------------

    fn submit(&mut self, sub: Submission) -> Result<JobId, AdmissionError> {
        self.stats.submitted += 1;
        let capacity = self.cfg.engine.max_workers;
        let min_workers = sub.workflow.ops.len();
        if capacity > 0 && min_workers > capacity {
            self.stats.rejected_too_large += 1;
            return Err(AdmissionError::TooLarge { min_workers, capacity });
        }
        let id = JobId(self.next_id);
        self.next_id += 1;

        // Cross-workflow result reuse: a fingerprint hit completes the
        // job without deploying a worker.
        let fingerprint = sub.cache_salt.map(|s| plan_fingerprint(&sub.workflow, s));
        if let Some(fp) = fingerprint {
            if let Some(rows) = self.cache.lookup(fp) {
                self.stats.cache_hits += 1;
                self.stats.completed += 1;
                self.jobs.insert(
                    id,
                    Job {
                        tenant: sub.tenant,
                        priority: sub.priority,
                        state: JobState::Finished(WorkflowResult {
                            id,
                            tenant: sub.tenant,
                            rows,
                            error: None,
                            cancelled: false,
                            cache_hit: true,
                            queued_s: 0.0,
                            total_s: 0.0,
                            measured_frt: Some(0.0),
                            workers_granted: 0,
                            preemptions: 0,
                        }),
                        waiters: Vec::new(),
                    },
                );
                return Ok(id);
            }
            self.stats.cache_misses += 1;
        }
        // Cache *writes* need the job's rows, which only a result sink
        // captures — a sink-less submission may still hit the cache
        // above but must never populate it (it would store an empty
        // row set and poison every later hit).
        let fingerprint = fingerprint.filter(|_| sub.result_sink.is_some());

        let quota = self.cfg.quota_of(sub.tenant);
        self.tenants.entry(sub.tenant).or_insert_with(|| TenantState {
            quota,
            running: 0,
        });
        let queued = QueuedJob {
            id,
            tenant: sub.tenant,
            priority: sub.priority,
            min_workers,
        };
        if let Err(e) = self.queue.push(queued, quota.max_queued) {
            match e {
                AdmissionError::QueueFull { .. } => self.stats.rejected_queue_full += 1,
                _ => self.stats.rejected_quota += 1,
            }
            return Err(e);
        }

        let mut config = sub.config.unwrap_or_else(|| self.cfg.engine.clone());
        // The service owns the budget; an Execution never re-applies it.
        config.max_workers = 0;
        // Per-job memory budget: the tenant's share of the service-wide
        // budget (`TenantQuota::max_memory_share`). The share is a cap,
        // not a grant — a per-job config override can tighten its own
        // budget further but never loosen it past the share, and an
        // unbounded service stays unbounded regardless of shares.
        let service_budget = self.cfg.engine.memory_budget_bytes;
        if service_budget > 0 {
            let share = quota.memory_allowance(service_budget);
            config.memory_budget_bytes = if config.memory_budget_bytes == 0 {
                share
            } else {
                config.memory_budget_bytes.min(share)
            };
        }
        let cost = sub
            .cost
            .unwrap_or_else(|| Self::default_cost(&self.cfg.engine, &sub.workflow));
        let sink_ops = sub.workflow.sinks();
        self.pending.insert(
            id,
            PendingJob {
                workflow: sub.workflow,
                config,
                cost,
                sink: sub.result_sink,
                sink_ops,
                fingerprint,
                submitted_at: Instant::now(),
            },
        );
        self.jobs.insert(
            id,
            Job {
                tenant: sub.tenant,
                priority: sub.priority,
                state: JobState::Queued,
                waiters: Vec::new(),
            },
        );
        self.stats.admitted += 1;
        self.live_jobs.fetch_add(1, Ordering::Relaxed);
        Ok(id)
    }

    /// Cost model for arbitration when the submission brings none:
    /// service defaults plus each source's `len_hint` (instantiating
    /// one throwaway source per scan — builders are pure factories).
    fn default_cost(engine: &Config, w: &Workflow) -> CostParams {
        let mut p = CostParams::from_config(engine);
        for (i, op) in w.ops.iter().enumerate() {
            if let Some(b) = op.source_builder.as_ref() {
                if let Some(n) = b(0, 1).len_hint() {
                    p.source_rows.insert(i, n as f64);
                }
            }
        }
        p
    }

    // ---- dispatch -----------------------------------------------------

    /// Resume preempted jobs, then start queued jobs, until the budget
    /// or the queue runs dry.
    fn drain(&mut self) {
        // Oldest-first resume sweep. A job blocked only by its *own*
        // tenant's worker allowance rotates to the back — other
        // tenants' parked jobs behind it must not starve; only the
        // *global* budget running dry stops the sweep.
        let mut left = self.preempted.len();
        while left > 0 {
            left -= 1;
            let Some(id) = self.preempted.pop_front() else { break };
            match self.try_resume_preempted(id) {
                Resume::Done => {}
                Resume::TenantCapped => self.preempted.push_back(id),
                Resume::BudgetFull => {
                    self.preempted.push_front(id);
                    break;
                }
            }
        }
        loop {
            // Eligibility covers every *per-tenant* gate (run cap,
            // worker share) so a capped tenant's job at the queue head
            // never blocks other tenants; only the *global* budget
            // check below stops the drain.
            let tenants = &self.tenants;
            let ledger = &self.ledger;
            let cfg = &self.cfg;
            let capacity = cfg.engine.max_workers;
            let Some(q) = self.queue.take_next(|j| {
                let run_ok = tenants
                    .get(&j.tenant)
                    .map(|t| t.running < t.quota.max_running)
                    .unwrap_or(true);
                if !run_ok {
                    return false;
                }
                let allowance = cfg
                    .quota_of(j.tenant)
                    .worker_allowance(capacity)
                    .saturating_sub(ledger.tenant_used(j.tenant));
                j.min_workers <= allowance
            }) else {
                break;
            };
            if !self.try_start(&q) {
                self.queue.push_front(q);
                break;
            }
        }
    }

    fn try_resume_preempted(&mut self, id: JobId) -> Resume {
        let Some(job) = self.jobs.get_mut(&id) else { return Resume::Done };
        let JobState::Running(run) = &mut job.state else { return Resume::Done };
        let footprint: usize = run.counts.iter().sum();
        let quota = self.cfg.quota_of(job.tenant);
        let allowance = quota.worker_allowance(self.cfg.engine.max_workers);
        if self.ledger.tenant_used(job.tenant) + footprint > allowance {
            return Resume::TenantCapped;
        }
        if !self.ledger.try_acquire(job.tenant, footprint) {
            return Resume::BudgetFull;
        }
        run.exec.resume();
        run.granted = footprint;
        run.preempted = false;
        self.stats.resumes += 1;
        Resume::Done
    }

    fn try_start(&mut self, q: &QueuedJob) -> bool {
        let capacity = self.cfg.engine.max_workers;
        let quota = self.cfg.quota_of(q.tenant);
        let allowance = quota
            .worker_allowance(capacity)
            .saturating_sub(self.ledger.tenant_used(q.tenant));
        if q.min_workers > allowance {
            return false;
        }
        if capacity > 0 && q.min_workers > self.ledger.available() {
            // Interactive jobs carve room out of running Batch jobs;
            // Batch jobs (and everything in FIFO mode) just wait.
            if q.priority != Priority::Interactive || self.cfg.fifo {
                return false;
            }
            self.preempt_for(q.min_workers);
            if q.min_workers > self.ledger.available() {
                return false;
            }
        }
        let Some(pend) = self.pending.remove(&q.id) else { return true };

        let slots = self.ledger.available().min(allowance);
        let counts: Vec<usize> = if capacity == 0 {
            pend.workflow.ops.iter().map(|o| o.workers).collect()
        } else {
            let weight = match q.priority {
                Priority::Interactive => self.cfg.interactive_weight,
                Priority::Batch => 1.0,
            };
            arbitrate(
                &[ArbiterJob {
                    workflow: &pend.workflow,
                    cost: &pend.cost,
                    weight,
                    fixed: HashMap::new(),
                }],
                slots,
            )
            .remove(0)
        };
        let total: usize = counts.iter().sum();
        if !self.ledger.try_acquire(q.tenant, total) {
            // Single-writer loop: arbitration never over-commits; keep
            // the defensive path anyway.
            self.pending.insert(q.id, pend);
            return false;
        }

        let mut w = pend.workflow;
        for (i, &c) in counts.iter().enumerate() {
            w.ops[i].workers = c;
        }
        let exec = Execution::start(w, pend.config);
        let done_rx = exec.on_done();
        let tx = self.tx.clone();
        let id = q.id;
        std::thread::Builder::new()
            .name(format!("svc-wait-{}", id.0))
            .spawn(move || {
                let summary = done_rx.recv().ok().map(Box::new);
                let _ = tx.send(Msg::JobFinished { id, summary });
            })
            .expect("spawn job waiter");

        if let Some(t) = self.tenants.get_mut(&q.tenant) {
            t.running += 1;
        }
        let job = self.jobs.get_mut(&q.id).expect("queued job known");
        job.state = JobState::Running(RunningJob {
            exec,
            counts,
            granted: total,
            granted_at_start: total,
            sink: pend.sink,
            sink_ops: pend.sink_ops,
            fingerprint: pend.fingerprint,
            submitted_at: pend.submitted_at,
            started_at: Instant::now(),
            preempted: false,
            user_paused: false,
            preemptions: 0,
        });
        true
    }

    /// Free budget for an Interactive job: first fence running Batch
    /// jobs down to one worker per operator, then pause-fence whole
    /// Batch jobs (largest grant first), releasing grants as they
    /// shrink, until `needed` workers are available.
    fn preempt_for(&mut self, needed: usize) {
        let mut victims: Vec<(JobId, usize)> = self
            .jobs
            .iter()
            .filter_map(|(&id, j)| match (&j.state, j.priority) {
                (JobState::Running(r), Priority::Batch)
                    if !r.preempted && !r.user_paused =>
                {
                    Some((id, r.granted))
                }
                _ => None,
            })
            .collect();
        victims.sort_by(|a, b| b.1.cmp(&a.1).then(a.0 .0.cmp(&b.0 .0)));

        // Phase 1: fenced scale-down to 1 worker per op.
        for &(id, _) in &victims {
            if self.ledger.available() >= needed {
                return;
            }
            let tenant = self.jobs[&id].tenant;
            let Some(job) = self.jobs.get_mut(&id) else { continue };
            let JobState::Running(run) = &mut job.state else { continue };
            let mut freed = 0usize;
            for op in 0..run.counts.len() {
                if run.counts[op] > 1
                    && run.exec.scale_operator(op, 1) > Duration::ZERO
                {
                    freed += run.counts[op] - 1;
                    run.counts[op] = 1;
                }
            }
            if freed > 0 {
                run.granted -= freed;
                self.ledger.release(tenant, freed);
            }
        }
        // Phase 2: pause-fence whole jobs, releasing their full grant
        // (threads park at the fence; the budget tracks runnable
        // workers).
        for &(id, _) in &victims {
            if self.ledger.available() >= needed {
                return;
            }
            let tenant = self.jobs[&id].tenant;
            let Some(job) = self.jobs.get_mut(&id) else { continue };
            let JobState::Running(run) = &mut job.state else { continue };
            let _ = run.exec.pause();
            self.ledger.release(tenant, run.granted);
            run.granted = 0;
            run.preempted = true;
            run.preemptions += 1;
            self.preempted.push_back(id);
            self.stats.preemptions += 1;
        }
    }

    // ---- job control --------------------------------------------------

    fn cancel(&mut self, id: JobId) -> bool {
        let Some(job) = self.jobs.get_mut(&id) else { return false };
        match &mut job.state {
            JobState::Queued => {
                self.queue.remove(id);
                self.pending.remove(&id);
                self.finalize(id, None, true);
                true
            }
            JobState::Running(_) => {
                self.finalize(id, None, true);
                true
            }
            JobState::Finished(_) => false,
        }
    }

    fn pause_job(&mut self, id: JobId) -> bool {
        let Some(job) = self.jobs.get_mut(&id) else { return false };
        let JobState::Running(run) = &mut job.state else { return false };
        if run.preempted || run.user_paused {
            return false;
        }
        let _ = run.exec.pause();
        run.user_paused = true;
        true
    }

    fn resume_job(&mut self, id: JobId) -> bool {
        let Some(job) = self.jobs.get_mut(&id) else { return false };
        let JobState::Running(run) = &mut job.state else { return false };
        if !run.user_paused {
            return false;
        }
        run.exec.resume();
        run.user_paused = false;
        true
    }

    fn scale_job(&mut self, id: JobId, op: usize, workers: usize) -> bool {
        if workers == 0 {
            return false;
        }
        let tenant = match self.jobs.get(&id) {
            Some(j) => j.tenant,
            None => return false,
        };
        let Some(job) = self.jobs.get_mut(&id) else { return false };
        let JobState::Running(run) = &mut job.state else { return false };
        if run.preempted || run.user_paused || op >= run.counts.len() {
            return false;
        }
        let cur = run.counts[op];
        if workers > cur {
            let extra = workers - cur;
            // A scale-up is bounded by the tenant's worker share just
            // like admission and resume — a tenant admitted at its
            // share must not grow past it through scale_job.
            let allowance = self
                .cfg
                .quota_of(tenant)
                .worker_allowance(self.cfg.engine.max_workers)
                .saturating_sub(self.ledger.tenant_used(tenant));
            if extra > allowance {
                return false;
            }
            if !self.ledger.try_acquire(tenant, extra) {
                return false;
            }
            if run.exec.scale_operator(op, workers) > Duration::ZERO {
                run.counts[op] = workers;
                run.granted += extra;
                true
            } else {
                self.ledger.release(tenant, extra);
                false
            }
        } else if workers < cur {
            if run.exec.scale_operator(op, workers) > Duration::ZERO {
                let freed = cur - workers;
                run.counts[op] = workers;
                run.granted -= freed;
                self.ledger.release(tenant, freed);
                true
            } else {
                false
            }
        } else {
            true
        }
    }

    fn migrate_job(&mut self, id: JobId, delta: PlanDelta) -> bool {
        let tenant = match self.jobs.get(&id) {
            Some(j) => j.tenant,
            None => return false,
        };
        let Some(job) = self.jobs.get_mut(&id) else { return false };
        let JobState::Running(run) = &mut job.state else { return false };
        if run.preempted || run.user_paused {
            return false;
        }
        match delta {
            PlanDelta::Repartition { .. } => {
                run.exec.migrate(delta).applied
            }
            PlanDelta::Replan { ref workers } => {
                // Settle the ledger exactly: acquire growth up front,
                // release the net shrink (or refund) after the fence.
                let mut extra = 0usize;
                for &(op, n) in workers {
                    if op < run.counts.len() && n > run.counts[op] {
                        extra += n - run.counts[op];
                    }
                }
                if extra > 0 {
                    // Same tenant-share bound as scale_job: Replan
                    // growth must not carry a tenant past its share.
                    let allowance = self
                        .cfg
                        .quota_of(tenant)
                        .worker_allowance(self.cfg.engine.max_workers)
                        .saturating_sub(self.ledger.tenant_used(tenant));
                    if extra > allowance || !self.ledger.try_acquire(tenant, extra) {
                        return false;
                    }
                }
                let outcome = run.exec.migrate(delta.clone());
                if !outcome.applied {
                    if extra > 0 {
                        self.ledger.release(tenant, extra);
                    }
                    return false;
                }
                let mut freed = 0usize;
                if let PlanDelta::Replan { workers } = delta {
                    for (op, n) in workers {
                        if op >= run.counts.len() {
                            continue;
                        }
                        if n > run.counts[op] {
                            run.granted += n - run.counts[op];
                        } else {
                            freed += run.counts[op] - n;
                            run.granted -= run.counts[op] - n;
                        }
                        run.counts[op] = n;
                    }
                }
                if freed > 0 {
                    self.ledger.release(tenant, freed);
                }
                true
            }
            // Mat splicing inserts/removes operators mid-flight; the
            // per-op grant bookkeeping cannot follow — refused here.
            PlanDelta::InsertMat { .. } | PlanDelta::RemoveMat { .. } => false,
        }
    }

    // ---- completion ---------------------------------------------------

    fn finish(&mut self, id: JobId, summary: Option<ExecSummary>) {
        let running = matches!(
            self.jobs.get(&id).map(|j| &j.state),
            Some(JobState::Running(_))
        );
        if running {
            self.finalize(id, summary, false);
        }
        // A stale JobFinished after a cancel finalized the job already
        // is dropped here.
    }

    /// Move a job to its terminal state: tear down the execution,
    /// settle the ledger, collect rows, feed the cache, fulfill
    /// waiters.
    fn finalize(&mut self, id: JobId, summary: Option<ExecSummary>, cancelled: bool) {
        let Some(job) = self.jobs.get_mut(&id) else { return };
        let tenant = job.tenant;
        let prev = std::mem::replace(&mut job.state, JobState::Queued);
        let result = match prev {
            JobState::Running(run) => {
                let RunningJob {
                    exec,
                    granted,
                    granted_at_start,
                    sink,
                    sink_ops,
                    fingerprint,
                    submitted_at,
                    started_at,
                    preempted,
                    user_paused,
                    preemptions,
                    ..
                } = run;
                // Un-park a paused job's workers before teardown, then
                // Drop tears the engine down (Shutdown + join) whether
                // the run completed or is being cancelled mid-flight.
                if preempted || user_paused {
                    exec.resume();
                }
                drop(exec);
                if granted > 0 {
                    self.ledger.release(tenant, granted);
                }
                self.preempted.retain(|&x| x != id);
                if let Some(t) = self.tenants.get_mut(&tenant) {
                    t.running = t.running.saturating_sub(1);
                }
                let rows = if cancelled {
                    Vec::new()
                } else {
                    sink.map(|h| h.tuples()).unwrap_or_default()
                };
                let error = summary.as_ref().and_then(|s| s.error.clone());
                let queued_s = (started_at - submitted_at).as_secs_f64();
                let measured_frt = summary.as_ref().and_then(|s| {
                    sink_ops
                        .iter()
                        .filter_map(|op| s.first_output.get(op).copied())
                        .fold(None, |m: Option<f64>, v| {
                            Some(m.map_or(v, |m| m.min(v)))
                        })
                        .map(|first| queued_s + first)
                });
                if !cancelled && error.is_none() {
                    if let Some(fp) = fingerprint {
                        self.cache.insert(fp, rows.clone());
                    }
                }
                WorkflowResult {
                    id,
                    tenant,
                    rows,
                    error,
                    cancelled,
                    cache_hit: false,
                    queued_s,
                    total_s: submitted_at.elapsed().as_secs_f64(),
                    measured_frt,
                    workers_granted: granted_at_start,
                    preemptions,
                }
            }
            JobState::Queued => {
                let queued_s = self
                    .pending
                    .get(&id)
                    .map(|p| p.submitted_at.elapsed().as_secs_f64())
                    .unwrap_or(0.0);
                WorkflowResult {
                    id,
                    tenant,
                    rows: Vec::new(),
                    error: None,
                    cancelled,
                    cache_hit: false,
                    queued_s,
                    total_s: queued_s,
                    measured_frt: None,
                    workers_granted: 0,
                    preemptions: 0,
                }
            }
            JobState::Finished(r) => r,
        };
        if cancelled {
            self.stats.cancelled += 1;
        } else if result.error.is_some() {
            self.stats.failed += 1;
        } else {
            self.stats.completed += 1;
        }
        self.live_jobs.fetch_sub(1, Ordering::Relaxed);
        let job = self.jobs.get_mut(&id).expect("job still present");
        if job.waiters.is_empty() {
            // Parked until the first wait collects (and evicts) it.
            job.state = JobState::Finished(result);
        } else {
            // Deliver-once: waiters already queued get the result now
            // and the job's entry (with its rows) is dropped outright.
            for w in job.waiters.drain(..) {
                let _ = w.send(Some(result.clone()));
            }
            self.jobs.remove(&id);
        }
    }

    fn shutdown(&mut self) {
        let queued: Vec<JobId> = self.queue.drain_all().iter().map(|q| q.id).collect();
        for id in queued {
            self.pending.remove(&id);
            self.finalize(id, None, true);
        }
        let running: Vec<JobId> = self
            .jobs
            .iter()
            .filter(|(_, j)| matches!(j.state, JobState::Running(_)))
            .map(|(&id, _)| id)
            .collect();
        for id in running {
            self.finalize(id, None, true);
        }
    }

    fn snapshot(&self) -> ServiceStats {
        let mut s = self.stats.clone();
        s.cache_hits = self.cache.hits();
        s.cache_misses = self.cache.misses();
        s.capacity = self.cfg.engine.max_workers;
        s.workers_in_use = self.ledger.used();
        s.peak_workers = self.ledger.peak();
        s.queued_now = self.queue.len();
        s.running_now = self
            .jobs
            .values()
            .filter(|j| matches!(j.state, JobState::Running(_)))
            .count();
        s
    }
}

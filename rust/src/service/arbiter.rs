//! Cross-workflow worker arbitration and the global budget ledger.
//!
//! [`arbitrate`] generalizes Maestro's per-region greedy allocator
//! ([`assign_workers`](crate::maestro::cost::assign_workers)) from
//! regions to **workflows**: every submitted workflow contributes its
//! one-to-one allocation groups to one pool, and the shared
//! marginal-gain loop ([`greedy_distribute`]) hands the global budget
//! out one group at a time, wherever the modeled time drop is largest.
//! A workflow is a *single allocation domain* — unlike Maestro's
//! region-sequential schedule, `Execution::start` deploys every worker
//! at once, so all of a workflow's groups are charged simultaneously.
//! For a single single-region workflow the arbitration is exactly
//! `assign_workers` (same groups, same gains, same strict-`>`
//! tie-breaking) — pinned by a property test in `tests/properties.rs`.
//!
//! [`WorkerLedger`] is the accounting side: an atomic running/peak
//! count of **runnable** workers charged against the capacity. Grants
//! gate deployment and scale-ups; preempting a job (pause-fence
//! quiesce) releases its grant even though its threads stay parked —
//! the Whiz-style decoupling of work allocation from compute. The
//! fuzzer invariant is `peak() <= capacity()` at every instant.

use crate::engine::dag::Workflow;
use crate::maestro::cost::{
    cardinalities, greedy_distribute, workflow_alloc_groups, AllocGroup, CostParams,
};
use crate::service::tenant::TenantId;
use std::collections::HashMap;
use std::sync::Mutex;

/// One workflow competing in an arbitration round.
pub struct ArbiterJob<'a> {
    pub workflow: &'a Workflow,
    pub cost: &'a CostParams,
    /// Priority weight multiplying the workflow's modeled work —
    /// interactive jobs bid more per modeled unit, so spare budget
    /// flows to them first. Relative gains *within* a workflow are
    /// unchanged by a uniform weight.
    pub weight: f64,
    /// Per-op pinned counts (a running job re-arbitrated alongside new
    /// ones keeps its current allocation).
    pub fixed: HashMap<usize, usize>,
}

/// Distribute `budget` workers across all jobs' operators at once.
/// Every one-to-one group starts at one worker per member (or its
/// `fixed` pin); spare budget beyond those minimums goes to the group
/// — in any workflow — with the largest weighted marginal gain.
/// Returns one count vector per job, indexed like its `workflow.ops`.
/// `budget == 0` means unbounded: every operator keeps its authored
/// count.
pub fn arbitrate(jobs: &[ArbiterJob<'_>], budget: usize) -> Vec<Vec<usize>> {
    if budget == 0 {
        return jobs
            .iter()
            .map(|j| j.workflow.ops.iter().map(|o| o.workers).collect())
            .collect();
    }
    // Flatten: (job index, member ops) per group, groups in per-job
    // one_to_one_groups order, jobs in argument order — deterministic.
    let mut groups: Vec<AllocGroup> = Vec::new();
    let mut owners: Vec<(usize, Vec<usize>)> = Vec::new();
    for (ji, job) in jobs.iter().enumerate() {
        let rows_out = cardinalities(job.workflow, job.cost);
        for (g, ops) in
            workflow_alloc_groups(job.workflow, &rows_out, job.cost, job.weight, &job.fixed)
        {
            groups.push(g);
            owners.push((ji, ops));
        }
    }
    let spent: usize = groups.iter().map(|g| g.count * g.members).sum();
    greedy_distribute(&mut groups, budget.saturating_sub(spent));
    let mut out: Vec<Vec<usize>> = jobs
        .iter()
        .map(|j| j.workflow.ops.iter().map(|o| o.workers).collect())
        .collect();
    for (g, (ji, ops)) in groups.iter().zip(&owners) {
        for &op in ops {
            out[*ji][op] = g.count;
        }
    }
    out
}

/// The global worker-budget ledger: how many runnable workers each
/// tenant currently holds, against a fixed capacity. All mutation goes
/// through [`try_acquire`](Self::try_acquire) /
/// [`release`](Self::release), so `peak()` is an exact high-water mark
/// — the fuzzer's never-exceeded invariant reads it directly.
/// `capacity == 0` disables the bound (grants always succeed; usage is
/// still tracked).
pub struct WorkerLedger {
    inner: Mutex<Inner>,
}

struct Inner {
    capacity: usize,
    used: usize,
    peak: usize,
    by_tenant: HashMap<TenantId, usize>,
}

impl WorkerLedger {
    pub fn new(capacity: usize) -> WorkerLedger {
        WorkerLedger {
            inner: Mutex::new(Inner {
                capacity,
                used: 0,
                peak: 0,
                by_tenant: HashMap::new(),
            }),
        }
    }

    /// Charge `n` workers to `tenant` if they fit; false leaves the
    /// ledger untouched.
    pub fn try_acquire(&self, tenant: TenantId, n: usize) -> bool {
        let mut g = self.inner.lock().unwrap();
        if g.capacity > 0 && g.used + n > g.capacity {
            return false;
        }
        g.used += n;
        g.peak = g.peak.max(g.used);
        *g.by_tenant.entry(tenant).or_insert(0) += n;
        true
    }

    /// Return `n` workers from `tenant`'s grant.
    pub fn release(&self, tenant: TenantId, n: usize) {
        let mut g = self.inner.lock().unwrap();
        debug_assert!(g.used >= n, "ledger release {n} exceeds used {}", g.used);
        g.used = g.used.saturating_sub(n);
        if let Some(t) = g.by_tenant.get_mut(&tenant) {
            *t = t.saturating_sub(n);
        }
    }

    pub fn capacity(&self) -> usize {
        self.inner.lock().unwrap().capacity
    }

    pub fn used(&self) -> usize {
        self.inner.lock().unwrap().used
    }

    /// High-water mark of `used` since creation.
    pub fn peak(&self) -> usize {
        self.inner.lock().unwrap().peak
    }

    /// Unused slots (`usize::MAX` when unbounded).
    pub fn available(&self) -> usize {
        let g = self.inner.lock().unwrap();
        if g.capacity == 0 {
            usize::MAX
        } else {
            g.capacity.saturating_sub(g.used)
        }
    }

    pub fn tenant_used(&self, tenant: TenantId) -> usize {
        self.inner
            .lock()
            .unwrap()
            .by_tenant
            .get(&tenant)
            .copied()
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ledger_bounds_and_peak() {
        let l = WorkerLedger::new(8);
        let t = TenantId(1);
        assert!(l.try_acquire(t, 5));
        assert!(!l.try_acquire(t, 4), "5+4 > 8 must refuse");
        assert!(l.try_acquire(t, 3));
        assert_eq!(l.used(), 8);
        assert_eq!(l.available(), 0);
        l.release(t, 6);
        assert_eq!(l.used(), 2);
        assert_eq!(l.tenant_used(t), 2);
        assert_eq!(l.peak(), 8, "peak is a high-water mark");
    }

    #[test]
    fn ledger_unbounded_when_capacity_zero() {
        let l = WorkerLedger::new(0);
        assert!(l.try_acquire(TenantId(7), 10_000));
        assert_eq!(l.available(), usize::MAX);
        assert_eq!(l.peak(), 10_000);
    }
}

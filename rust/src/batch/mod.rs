//! A stage-by-stage batch engine — the Spark stand-in for the Ch. 2
//! comparison experiments (Figs. 2.14–2.16).
//!
//! Executes the same [`Workflow`] and [`Operator`]s as the pipelined
//! engine but in the batch model: operators run in topological order,
//! every operator's full output is **materialized** before its
//! consumers start (the stage barrier), and optional checkpointing
//! writes each stage's partitions to disk. Two checkpoint layouts
//! reproduce the Fig. 2.16 file-count effect:
//!
//! * [`FileLayout::PerPartition`] — one file per (producer worker ×
//!   hash partition), like Amber's workers ("Amber produced 400 files
//!   (20 workers, each producing 20 partitions)");
//! * [`FileLayout::Consolidated`] — block-sized files like Spark's
//!   128 MB HDFS blocks.

use crate::engine::dag::Workflow;
use crate::engine::operator::{Emitter, Operator};
use crate::engine::partitioner::{PartitionScheme, Partitioner};
use crate::tuple::Tuple;
use std::io::Write;
use std::time::{Duration, Instant};

/// Checkpoint file layout.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FileLayout {
    /// One file per (worker, partition) — quadratic in workers.
    PerPartition,
    /// Consolidate into files of `block_bytes`.
    Consolidated { block_bytes: usize },
}

/// Batch-engine configuration.
#[derive(Clone, Debug)]
pub struct BatchConfig {
    /// Checkpoint stage outputs into this directory (None = off).
    pub checkpoint_dir: Option<String>,
    pub layout: FileLayout,
}

impl Default for BatchConfig {
    fn default() -> BatchConfig {
        BatchConfig {
            checkpoint_dir: None,
            layout: FileLayout::Consolidated { block_bytes: 1 << 20 },
        }
    }
}

/// Result of a batch run.
#[derive(Debug, Default)]
pub struct BatchSummary {
    pub elapsed: Duration,
    /// Rows produced by each operator.
    pub produced: Vec<u64>,
    /// Checkpoint files written.
    pub files_written: usize,
    /// Checkpoint bytes written.
    pub bytes_written: u64,
}

struct PartitionEmitter {
    parts: Vec<Vec<Tuple>>,
    partitioner: Partitioner,
}

impl Emitter for PartitionEmitter {
    fn emit(&mut self, t: Tuple) {
        let d = self.partitioner.route(&t);
        if d == usize::MAX {
            for p in self.parts.iter_mut() {
                p.push(t.clone());
            }
        } else {
            self.parts[d].push(t);
        }
    }
}

/// Execute a workflow in batch mode.
pub fn run_batch(w: &Workflow, cfg: &BatchConfig) -> BatchSummary {
    w.validate().expect("invalid workflow");
    let t0 = Instant::now();
    let order = w.topo_order();
    // outputs[op][consumer_worker] = tuples routed there, per edge key
    // (op, to, to_port). Simplify: store per op a vec of output rows per
    // *edge*, partitioned for that edge's destination.
    let mut edge_outputs: Vec<Vec<Vec<Tuple>>> = vec![Vec::new(); w.edges.len()];
    let mut summary = BatchSummary { produced: vec![0; w.ops.len()], ..Default::default() };
    let mut files = 0usize;
    let mut bytes = 0u64;

    for &op_idx in &order {
        let spec = &w.ops[op_idx];
        let nworkers = spec.workers;
        // Instantiate workers.
        let mut ops: Vec<Box<dyn Operator>> =
            (0..nworkers).map(|i| (spec.builder)(i, nworkers)).collect();
        // Per out-edge emitters (one per worker).
        let out_edges = w.out_edges(op_idx);
        let mut emitters: Vec<Vec<PartitionEmitter>> = (0..nworkers)
            .map(|widx| {
                out_edges
                    .iter()
                    .map(|e| {
                        let dst_workers = w.ops[e.to].workers;
                        let scheme = w.ops[e.to].input_partitioning[e.to_port].clone();
                        PartitionEmitter {
                            parts: vec![Vec::new(); dst_workers],
                            partitioner: Partitioner::new(scheme, dst_workers, widx),
                        }
                    })
                    .collect()
            })
            .collect();

        // Feed inputs. Port order: blocking ports first (build before
        // probe — the batch model always satisfies this).
        let mut in_edges = w.in_edges(op_idx);
        in_edges.sort_by_key(|e| {
            if spec.blocking_ports.contains(&e.to_port) {
                (0, e.to_port)
            } else {
                (1, e.to_port)
            }
        });
        let mut seen_ports: Vec<usize> = Vec::new();
        for e in &in_edges {
            let ei = w.edges.iter().position(|x| x == e).unwrap();
            for widx in 0..nworkers {
                let rows = std::mem::take(&mut edge_outputs[ei][widx]);
                for t in rows {
                    for (eo, em) in emitters[widx].iter_mut().enumerate() {
                        let _ = eo;
                        let _ = em;
                    }
                    // process with a multi-emitter wrapper below.
                    process_one(&mut ops[widx], t, e.to_port, &mut emitters[widx]);
                }
            }
            if !seen_ports.contains(&e.to_port) {
                seen_ports.push(e.to_port);
            }
            // Port EOF after all edges for that port are consumed.
            let port_done = in_edges
                .iter()
                .filter(|x| x.to_port == e.to_port)
                .all(|x| {
                    let xi = w.edges.iter().position(|y| y == x).unwrap();
                    edge_outputs[xi].iter().all(|v| v.is_empty())
                });
            if port_done {
                for widx in 0..nworkers {
                    finish_port_multi(&mut ops[widx], e.to_port, &mut emitters[widx]);
                }
            }
        }
        // Source operators generate.
        if spec.is_source {
            for (widx, op) in ops.iter_mut().enumerate() {
                let mut src = (spec.source_builder.as_ref().unwrap())(widx, nworkers);
                while let Some(t) = src.next_tuple() {
                    process_one(op, t, 0, &mut emitters[widx]);
                }
            }
        }
        // Final finish.
        for (widx, op) in ops.iter_mut().enumerate() {
            finish_multi(op, &mut emitters[widx]);
        }
        // Collect outputs per edge; stage barrier + optional checkpoint.
        for (eo, e) in out_edges.iter().enumerate() {
            let ei = w.edges.iter().position(|x| x == e).unwrap();
            let dst_workers = w.ops[e.to].workers;
            let mut merged: Vec<Vec<Tuple>> = vec![Vec::new(); dst_workers];
            for widx in 0..nworkers {
                // Checkpoint per (worker, partition) before merging.
                if let Some(dir) = &cfg.checkpoint_dir {
                    match cfg.layout {
                        FileLayout::PerPartition => {
                            for (p, rows) in emitters[widx][eo].parts.iter().enumerate() {
                                if !rows.is_empty() {
                                    let (f, b) = write_file(
                                        dir,
                                        &format!("op{op_idx}_w{widx}_p{p}"),
                                        rows,
                                    );
                                    files += f;
                                    bytes += b;
                                }
                            }
                        }
                        FileLayout::Consolidated { .. } => { /* below */ }
                    }
                }
                for (p, rows) in emitters[widx][eo].parts.iter_mut().enumerate() {
                    summary.produced[op_idx] += rows.len() as u64;
                    merged[p].append(rows);
                }
            }
            if let (Some(dir), FileLayout::Consolidated { block_bytes }) =
                (&cfg.checkpoint_dir, cfg.layout)
            {
                // Consolidated blocks across the stage output.
                let mut buf: Vec<&Tuple> = Vec::new();
                let mut cur = 0usize;
                for part in &merged {
                    for t in part {
                        cur += t.byte_size();
                        buf.push(t);
                        if cur >= block_bytes {
                            let rows: Vec<Tuple> = buf.drain(..).cloned().collect();
                            let (f, b) =
                                write_file(dir, &format!("op{op_idx}_blk{files}"), &rows);
                            files += f;
                            bytes += b;
                            cur = 0;
                        }
                    }
                }
                if !buf.is_empty() {
                    let rows: Vec<Tuple> = buf.drain(..).cloned().collect();
                    let (f, b) = write_file(dir, &format!("op{op_idx}_blk{files}"), &rows);
                    files += f;
                    bytes += b;
                }
            }
            edge_outputs[ei] = merged;
        }
        // Sinks produce nothing; count their processed rows as produced
        // for reporting parity.
    }
    summary.files_written = files;
    summary.bytes_written = bytes;
    summary.elapsed = t0.elapsed();
    summary
}

fn process_one(op: &mut Box<dyn Operator>, t: Tuple, port: usize, ems: &mut [PartitionEmitter]) {
    let mut multi = MultiEmitter { ems };
    op.process(t, port, &mut multi);
}

fn finish_port_multi(op: &mut Box<dyn Operator>, port: usize, ems: &mut [PartitionEmitter]) {
    let mut multi = MultiEmitter { ems };
    op.finish_port(port, &mut multi);
}

fn finish_multi(op: &mut Box<dyn Operator>, ems: &mut [PartitionEmitter]) {
    let mut multi = MultiEmitter { ems };
    op.finish(&mut multi);
}

struct MultiEmitter<'a> {
    ems: &'a mut [PartitionEmitter],
}

impl Emitter for MultiEmitter<'_> {
    fn emit(&mut self, t: Tuple) {
        for em in self.ems.iter_mut() {
            em.emit(t.clone());
        }
    }
}

fn write_file(dir: &str, name: &str, rows: &[Tuple]) -> (usize, u64) {
    let _ = std::fs::create_dir_all(dir);
    let path = format!("{dir}/{name}.part");
    let mut f = std::fs::File::create(&path).expect("checkpoint write");
    let mut written = 0u64;
    // Simple line-ish serialization; the experiment measures IO volume
    // and file-count overhead, not a storage format.
    let mut buf = String::new();
    for t in rows {
        buf.push_str(&format!("{t}\n"));
    }
    f.write_all(buf.as_bytes()).expect("checkpoint write");
    written += buf.len() as u64;
    (1, written)
}

/// Placeholder scheme export so workflows built for the pipelined
/// engine run unchanged (both engines consume [`PartitionScheme`]).
pub fn _scheme_reexport() -> PartitionScheme {
    PartitionScheme::RoundRobin
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::dag::OpSpec;
    use crate::operators::basic::{Cmp, Filter};
    use crate::operators::{AggKind, GroupByFinal, GroupByPartial, HashJoin};
    use crate::tuple::Value;
    use crate::workloads::VecSource;

    fn int_rows(n: usize) -> Vec<Tuple> {
        (0..n)
            .map(|i| Tuple::new(vec![Value::Int(i as i64), Value::Int((i % 10) as i64)]))
            .collect()
    }

    #[test]
    fn batch_filter_counts_match() {
        let mut w = Workflow::new();
        let rows = int_rows(1000);
        let s = w.add(OpSpec::source("scan", 2, move |idx, parts| {
            let data: Vec<Tuple> = rows
                .iter()
                .enumerate()
                .filter(|(i, _)| i % parts == idx)
                .map(|(_, t)| t.clone())
                .collect();
            Box::new(VecSource::new(data))
        }));
        let f = w.add(OpSpec::unary("filter", 2, PartitionScheme::RoundRobin, |_, _| {
            Box::new(Filter::new(0, Cmp::Lt, Value::Int(100)))
        }));
        w.connect(s, f, 0);
        let summary = run_batch(&w, &BatchConfig::default());
        assert_eq!(summary.produced[s], 1000);
        // filter has no out-edges (it is the sink) → produced not
        // tracked through edges; verify via scan count only.
        assert_eq!(summary.files_written, 0);
    }

    #[test]
    fn batch_join_equals_pipelined_semantics() {
        let mut w = Workflow::new();
        let b = w.add(OpSpec::source("build", 1, |_, _| {
            Box::new(VecSource::new(
                (0..10).map(|k| Tuple::new(vec![Value::Int(k)])).collect(),
            ))
        }));
        let p = w.add(OpSpec::source("probe", 1, |_, _| {
            Box::new(VecSource::new(
                (0..200).map(|i| Tuple::new(vec![Value::Int(i % 10)])).collect(),
            ))
        }));
        let j = w.add(OpSpec::binary(
            "join",
            3,
            [PartitionScheme::Hash { key: 0 }, PartitionScheme::Hash { key: 0 }],
            vec![0],
            |_, _| Box::new(HashJoin::new(0, 0)),
        ));
        let sinkop = w.add(OpSpec::unary("sink", 1, PartitionScheme::RoundRobin, |_, _| {
            Box::new(crate::engine::dag::PassThrough)
        }));
        w.connect(b, j, 0);
        w.connect(p, j, 1);
        w.connect(j, sinkop, 0);
        let summary = run_batch(&w, &BatchConfig::default());
        assert_eq!(summary.produced[j], 200);
    }

    #[test]
    fn batch_group_by_results() {
        let mut w = Workflow::new();
        let rows = int_rows(500);
        let s = w.add(OpSpec::source("scan", 2, move |idx, parts| {
            let data: Vec<Tuple> = rows
                .iter()
                .enumerate()
                .filter(|(i, _)| i % parts == idx)
                .map(|(_, t)| t.clone())
                .collect();
            Box::new(VecSource::new(data))
        }));
        let gp = w.add(OpSpec::unary("partial", 2, PartitionScheme::RoundRobin, |_, _| {
            Box::new(GroupByPartial::new(1, 0, AggKind::Count))
        }));
        let gf = w.add(
            OpSpec::unary("final", 2, PartitionScheme::Hash { key: 0 }, |_, _| {
                Box::new(GroupByFinal::new(AggKind::Count))
            })
            .with_blocking(vec![0]),
        );
        let sink = w.add(OpSpec::unary("sink", 1, PartitionScheme::RoundRobin, |_, _| {
            Box::new(crate::engine::dag::PassThrough)
        }));
        w.connect(s, gp, 0);
        w.connect(gp, gf, 0);
        w.connect(gf, sink, 0);
        let summary = run_batch(&w, &BatchConfig::default());
        assert_eq!(summary.produced[gf], 10, "10 groups");
    }

    #[test]
    fn checkpoint_file_counts_differ_by_layout() {
        let build = |layout| {
            let mut w = Workflow::new();
            let rows = int_rows(2000);
            let s = w.add(OpSpec::source("scan", 4, move |idx, parts| {
                let data: Vec<Tuple> = rows
                    .iter()
                    .enumerate()
                    .filter(|(i, _)| i % parts == idx)
                    .map(|(_, t)| t.clone())
                    .collect();
                Box::new(VecSource::new(data))
            }));
            let g = w.add(
                OpSpec::unary("gb", 4, PartitionScheme::Hash { key: 1 }, |_, _| {
                    Box::new(GroupByPartial::new(1, 0, AggKind::Count))
                }),
            );
            let sink = w.add(OpSpec::unary("sink", 1, PartitionScheme::RoundRobin, |_, _| {
                Box::new(crate::engine::dag::PassThrough)
            }));
            w.connect(s, g, 0);
            w.connect(g, sink, 0);
            let dir = format!(
                "/tmp/amber_batch_test_{}",
                std::time::SystemTime::now()
                    .duration_since(std::time::UNIX_EPOCH)
                    .unwrap()
                    .as_nanos()
            );
            let cfg = BatchConfig { checkpoint_dir: Some(dir.clone()), layout };
            let s = run_batch(&w, &cfg);
            let _ = std::fs::remove_dir_all(dir);
            s
        };
        let per_part = build(FileLayout::PerPartition);
        let consolidated = build(FileLayout::Consolidated { block_bytes: 1 << 20 });
        assert!(
            per_part.files_written > consolidated.files_written,
            "{} !> {}",
            per_part.files_written,
            consolidated.files_written
        );
        assert!(per_part.bytes_written > 0);
    }
}

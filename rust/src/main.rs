//! `amber` — the launcher CLI.
//!
//! ```text
//! amber run <q1|q13|sort|tweets> [--workers N] [--sf X] [--reshape]
//! amber corpus                    # Table 4.1 workflow analysis
//! amber inspect <q1|q13|sort>     # region analysis of a workflow
//! amber serve [--jobs M] [--tenants N] [--budget W] [--sf X] [--fifo]
//! ```
//!
//! The experiment harnesses that regenerate the paper's tables and
//! figures run under `cargo bench` (see rust/benches/); this binary is
//! the interactive entry point.

use texera_amber::config::Config;
use texera_amber::engine::Execution;
use texera_amber::flows;
use texera_amber::maestro::corpus;
use texera_amber::maestro::region_graph::region_graph;
use texera_amber::reshape::{Approach, ReshapePlugin};
use texera_amber::service::{EngineService, ServiceConfig, Submission, TenantId};
use texera_amber::util::cli::Args;
use texera_amber::workloads::tweets;

fn main() {
    let args = Args::from_env();
    match args.positional.first().map(String::as_str) {
        Some("run") => cmd_run(&args),
        Some("corpus") => cmd_corpus(),
        Some("inspect") => cmd_inspect(&args),
        Some("serve") => cmd_serve(&args),
        _ => {
            eprintln!("usage: amber <run|corpus|inspect|serve> [...]");
            eprintln!("  amber run q1 --sf 1.0 --workers 8           # TPC-H Q1-style");
            eprintln!("  amber run q13 --sf 1.0 --workers 8          # Q13-style join");
            eprintln!("  amber run sort --sf 1.0 --workers 4         # range sort");
            eprintln!("  amber run tweets --tweets 300000 --reshape  # skewed join");
            eprintln!("  amber corpus                                # Table 4.1");
            eprintln!("  amber inspect q13                           # region analysis");
            eprintln!("  amber serve --jobs 8 --tenants 3 --budget 8 # multi-tenant demo");
            std::process::exit(2);
        }
    }
}

fn flow_by_name(name: &str, sf: f64, workers: usize) -> Option<flows::Flow> {
    match name {
        "q1" => Some(flows::tpch_q1(sf, workers)),
        "q13" => Some(flows::tpch_q13(sf, workers)),
        "sort" => Some(flows::orders_sort(sf, workers)),
        _ => None,
    }
}

fn cmd_run(args: &Args) {
    let name = args.positional.get(1).map(String::as_str).unwrap_or("q1");
    let workers: usize = args.get("workers", 4);
    let sf: f64 = args.get("sf", 0.5);
    if name == "tweets" {
        let total: usize = args.get("tweets", 300_000);
        let f = flows::tweet_join(total, workers.max(4), 0x77E3);
        let cfg = Config { batch_size: 64, data_queue_cap: 16, ..Config::default() };
        let exec = if args.has("reshape") {
            let plugin = ReshapePlugin::new(f.focus, Approach::SplitByRecords, true);
            Execution::start_with_plugin(f.workflow, cfg, Box::new(plugin))
        } else {
            Execution::start(f.workflow, cfg)
        };
        let s = exec.join();
        println!(
            "tweet join: {:.2?}, {} results, CA:AZ {:.2} (actual {})",
            s.elapsed,
            f.sink.total(),
            f.sink.ratio(tweets::CA, tweets::AZ),
            tweets::CA_AZ_RATIO
        );
        return;
    }
    let Some(f) = flow_by_name(name, sf, workers) else {
        eprintln!("unknown workflow {name}");
        std::process::exit(2);
    };
    let exec = Execution::start(f.workflow, Config::default());
    let s = exec.join();
    println!(
        "{name}: {:.2?}, {} result rows, first-output[focus] {:?}s",
        s.elapsed,
        f.sink.total(),
        s.first_output.get(&f.focus)
    );
}

/// Multi-tenant serving demo: M workflows from N tenants race through
/// one `EngineService` under a global worker budget. Every third job is
/// submitted as Interactive so preemption/priority shows up in the
/// printed latencies.
fn cmd_serve(args: &Args) {
    let jobs: usize = args.get("jobs", 8);
    let tenants: usize = args.get("tenants", 3);
    let budget: usize = args.get("budget", 8);
    let sf: f64 = args.get("sf", 0.1);
    let cfg = ServiceConfig {
        engine: Config { max_workers: budget, ..Config::default() },
        fifo: args.has("fifo"),
        ..ServiceConfig::default()
    };
    let svc = EngineService::start(cfg);
    println!(
        "serving {jobs} jobs from {} tenants, budget {budget} workers, {} admission",
        tenants.max(1),
        if args.has("fifo") { "fifo" } else { "priority" }
    );
    let mut ids = Vec::new();
    for i in 0..jobs {
        let f = if i % 2 == 0 {
            flows::tpch_q1(sf, 2)
        } else {
            flows::orders_sort(sf, 2)
        };
        let tenant = TenantId((i % tenants.max(1)) as u64);
        let mut sub = Submission::new(tenant, f.workflow).with_sink(f.sink.clone());
        if i % 3 == 0 {
            sub = sub.interactive();
        }
        match svc.submit(sub) {
            Ok(id) => ids.push((id, tenant, i % 3 == 0, f.sink)),
            Err(e) => println!("  job {i} rejected: {e}"),
        }
    }
    for (id, tenant, interactive, sink) in ids {
        let r = svc.wait(id).expect("submitted job finishes");
        println!(
            "  job {:>3} {tenant} {}: {} rows, queued {:.0}ms, total {:.0}ms, frt {}, {} workers{}",
            id.0,
            if interactive { "inter" } else { "batch" },
            sink.total(),
            r.queued_s * 1e3,
            r.total_s * 1e3,
            r.measured_frt.map_or_else(|| "n/a".into(), |s: f64| format!("{:.0}ms", s * 1e3)),
            r.workers_granted,
            if r.preemptions > 0 { format!(", preempted ×{}", r.preemptions) } else { String::new() },
        );
    }
    let s = svc.stats();
    println!(
        "stats: {} submitted, {} completed, {} failed, peak {}/{} workers, {} preemptions, {} cache hits",
        s.submitted, s.completed, s.failed, s.peak_workers, s.capacity, s.preemptions, s.cache_hits
    );
}

fn cmd_corpus() {
    println!(
        "{:<12} {:<22} {:>4} {:>6} {:>6} {:>8} {:>7} {:>8}",
        "system", "workflow", "ops", "multi", "block", "regions", "cyclic", "choices"
    );
    for r in corpus::analyze() {
        println!(
            "{:<12} {:<22} {:>4} {:>6} {:>6} {:>8} {:>7} {:>8}",
            r.system,
            r.name,
            r.operators,
            r.multi_input_ops,
            r.blocking_links,
            r.regions,
            r.cyclic,
            r.materialization_choices
        );
    }
}

fn cmd_inspect(args: &Args) {
    let name = args.positional.get(1).map(String::as_str).unwrap_or("q13");
    let Some(f) = flow_by_name(name, 0.1, 2) else {
        eprintln!("unknown workflow {name}");
        std::process::exit(2);
    };
    let w = &f.workflow;
    let g = region_graph(w);
    println!("{name}: {} operators, {} regions", w.ops.len(), g.regions.len());
    for r in &g.regions {
        let names: Vec<&str> = r.ops.iter().map(|&o| w.ops[o].name.as_str()).collect();
        println!("  region {}: {names:?}", r.id);
    }
    println!("acyclic: {}", g.is_acyclic());
}

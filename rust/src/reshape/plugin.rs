//! The Reshape coordinator plugin: the full mitigation protocol of
//! Fig. 3.2 running inside the engine coordinator.
//!
//! Per tick (one metric-collection period):
//! 1. read each worker's workload φ (queue size, or busy-time in the
//!    Flink-style configuration) and feed the per-worker
//!    [`MeanEstimator`]s with base-partitioning receipt rates;
//! 2. advance active mitigations: state-transfer → **phase 1**
//!    (catch-up) → **phase 2** (rebalance from predictions), iterating
//!    on divergence (§3.4.3.1);
//! 3. run the skew test over unmitigated workers, pick helpers, and
//!    start new mitigations (state migration first, Fig. 3.2(b–d));
//! 4. adjust τ per Algorithm 1 when enabled.
//!
//! The plugin records a [`ReshapeReport`] (shared, lock-guarded) the
//! experiment harnesses read: per-pair received-tuples timelines, τ
//! history, iteration counts.

use crate::engine::controller::{CoordPlugin, PluginCtx};
use crate::engine::message::{ControlMessage, WorkerEvent, WorkerId};
use crate::engine::partitioner::{MitigationRoute, ShareMode};
use crate::reshape::adaptive::{adjust_tau, TauDecision};
use crate::reshape::detector;
use crate::reshape::estimator::MeanEstimator;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Load-transfer approach (§3.3.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Approach {
    /// Split by records: a fraction of *every* key's tuples moves —
    /// representative early results, no input-order preservation.
    SplitByRecords,
    /// Split by keys: whole keys move — preserves per-key order,
    /// cannot split a heavy hitter.
    SplitByKeys,
}

/// Denominator of SBR record-split windows (num/1000 of every 1000).
const SBR_DEN: u32 = 1000;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Phase {
    /// Waiting for state-transfer acks from helpers (Fig. 3.2(c,d)).
    AwaitState { outstanding: usize },
    /// Phase 1: helpers catching up with the backlog (§3.3.2).
    CatchUp,
    /// Phase 2: steady-state rebalancing of future input.
    Rebalance,
}

#[derive(Debug)]
struct Mitigation {
    skewed: usize,
    helpers: Vec<usize>,
    phase: Phase,
    iterations: u32,
}

/// Shared observability record for the experiment harnesses.
#[derive(Debug, Default)]
pub struct ReshapeReport {
    /// Mitigations started: (elapsed s, skewed, helpers).
    pub mitigations: Vec<(f64, usize, Vec<usize>)>,
    /// Phase-2 activations: (elapsed s, skewed).
    pub phase2: Vec<(f64, usize)>,
    /// Total mitigation iterations (phase-2 recomputations included).
    pub iterations: u32,
    /// τ value over time: (elapsed s, τ).
    pub tau_history: Vec<(f64, f64)>,
    /// Per tick: (elapsed s, worker idx, received σ_w, workload φ).
    pub timeline: Vec<(f64, usize, i64, f64)>,
    /// State-transfer acks observed: (elapsed s, transfer id).
    pub transfers: Vec<(f64, u64)>,
}

/// The Reshape plugin. Protects one operator (`target_op`).
pub struct ReshapePlugin {
    target_op: usize,
    approach: Approach,
    /// Workers of ops feeding `target_op` get route updates.
    mitigations: Vec<Mitigation>,
    estimators: Vec<MeanEstimator>,
    last_base: Vec<i64>,
    tau: f64,
    tau_adjustments: u32,
    epoch: u64,
    next_transfer: u64,
    /// transfer id → mitigation index.
    pending_transfers: Vec<(u64, usize)>,
    /// SBK moves on mutable-state operators awaiting marker alignment
    /// (§3.5.3): epoch → (skewed, helper, keys).
    pending_sbk_moves: Vec<(u64, usize, usize, Vec<u64>)>,
    /// The protected operator's state is immutable in its current
    /// phase (probe-side join) → replicate on migration; otherwise
    /// move/skip per §3.5.
    immutable_state: bool,
    /// Run the catch-up first phase (§3.3.2). Disabled only by the
    /// Fig. 3.18/3.19 ablation.
    phase1_enabled: bool,
    report: Arc<Mutex<ReshapeReport>>,
    ticks: u64,
}

impl ReshapePlugin {
    /// Protect `target_op` with the given approach. `immutable_state`
    /// = the mitigated phase's state is immutable (Table 3.1) and is
    /// replicated to helpers before load transfer.
    pub fn new(target_op: usize, approach: Approach, immutable_state: bool) -> ReshapePlugin {
        ReshapePlugin {
            target_op,
            approach,
            mitigations: Vec::new(),
            estimators: Vec::new(),
            last_base: Vec::new(),
            tau: f64::NAN, // initialized from config on first tick
            tau_adjustments: 0,
            epoch: 0,
            next_transfer: 1,
            pending_transfers: Vec::new(),
            pending_sbk_moves: Vec::new(),
            immutable_state,
            phase1_enabled: true,
            report: Arc::new(Mutex::new(ReshapeReport::default())),
            ticks: 0,
        }
    }

    /// Ablation (Figs. 3.18/3.19): skip the catch-up phase and go
    /// straight to estimator-driven rebalancing.
    pub fn without_phase1(mut self) -> ReshapePlugin {
        self.phase1_enabled = false;
        self
    }

    /// Shared report handle for harnesses.
    pub fn report(&self) -> Arc<Mutex<ReshapeReport>> {
        self.report.clone()
    }

    fn workloads(&self, ctx: &PluginCtx) -> Vec<f64> {
        let n = ctx.workers_of(self.target_op);
        (0..n)
            .map(|i| {
                let id = WorkerId::new(self.target_op, i);
                if ctx.completed.contains(&id) {
                    return 0.0;
                }
                let Some(g) = ctx.gauges_of(id) else { return 0.0 };
                match ctx.config.reshape_metric {
                    crate::config::WorkloadMetric::QueueSize => {
                        g.queued.load(Ordering::Relaxed).max(0) as f64
                    }
                    crate::config::WorkloadMetric::BusyTime => {
                        g.busy_fraction(std::time::Instant::now(), ctx.started) * 100.0
                    }
                }
            })
            .collect()
    }

    /// (η, τ) in the units of the configured metric.
    fn thresholds(&self, ctx: &PluginCtx) -> (f64, f64) {
        match ctx.config.reshape_metric {
            crate::config::WorkloadMetric::QueueSize => (ctx.config.reshape_eta, self.tau),
            crate::config::WorkloadMetric::BusyTime => {
                (ctx.config.reshape_busy_threshold * 100.0, 10.0)
            }
        }
    }

    /// Broadcast a route to every worker of every upstream operator.
    fn push_route(&mut self, ctx: &PluginCtx, skewed: usize, helper: usize, mode: ShareMode) {
        self.epoch += 1;
        for up in ctx.upstream_ops(self.target_op) {
            ctx.broadcast(
                up,
                ControlMessage::UpdateRoute {
                    target_op: self.target_op,
                    route: MitigationRoute {
                        skewed,
                        helper,
                        mode: mode.clone(),
                        epoch: self.epoch,
                    },
                },
            );
        }
    }

    /// Keys (stable hashes) to move for SBK, chosen from the skewed
    /// worker's per-key distribution so their combined load ≈
    /// `fraction` of its input. Heaviest key is splittable only under
    /// SBR, so SBK keeps it (the Flux limitation is stricter — see
    /// baselines).
    fn pick_keys(&self, ctx: &PluginCtx, skewed: usize, fraction: f64) -> Vec<u64> {
        let id = WorkerId::new(self.target_op, skewed);
        let Some(g) = ctx.gauges_of(id) else { return Vec::new() };
        let counts = g.key_counts.lock().unwrap();
        let mut items: Vec<(u64, u64)> = counts.iter().map(|(k, v)| (*k, *v)).collect();
        drop(counts);
        items.sort_by_key(|(_, c)| std::cmp::Reverse(*c));
        let total: u64 = items.iter().map(|(_, c)| c).sum();
        if total == 0 || items.len() < 2 {
            return Vec::new();
        }
        // Skip the heaviest key; greedily take next-heaviest keys until
        // the requested fraction is covered.
        let mut moved = Vec::new();
        let mut acc = 0u64;
        for (k, c) in items.into_iter().skip(1) {
            if (acc as f64) / (total as f64) >= fraction {
                break;
            }
            moved.push(k);
            acc += c;
        }
        moved
    }

    /// Enter phase 2 for a mitigation: compute per-helper shares from
    /// the estimators and install the rebalancing routes.
    fn start_phase2(&mut self, ctx: &PluginCtx, mi: usize) {
        let (skewed, helpers) = {
            let m = &self.mitigations[mi];
            (m.skewed, m.helpers.clone())
        };
        let est_s = self.estimators[skewed].predict();
        let est_h: Vec<f64> = helpers.iter().map(|&h| self.estimators[h].predict()).collect();
        let mean = (est_s + est_h.iter().sum::<f64>()) / (helpers.len() as f64 + 1.0);
        match self.approach {
            Approach::SplitByRecords => {
                for (i, &h) in helpers.iter().enumerate() {
                    let extra = (mean - est_h[i]).max(0.0);
                    let frac = if est_s > 0.0 { (extra / est_s).min(0.95) } else { 0.0 };
                    let num = ((frac * SBR_DEN as f64).round() as u32).min(SBR_DEN - 1);
                    self.push_route(
                        ctx,
                        skewed,
                        h,
                        ShareMode::SplitRecords { num: num.max(1), den: SBR_DEN },
                    );
                }
            }
            Approach::SplitByKeys => {
                for (i, &h) in helpers.iter().enumerate() {
                    let extra = (mean - est_h[i]).max(0.0);
                    let frac = if est_s > 0.0 { (extra / est_s).min(0.95) } else { 0.0 };
                    let keys = self.pick_keys(ctx, skewed, frac);
                    if !keys.is_empty() {
                        self.push_route(ctx, skewed, h, ShareMode::SplitKeys(keys.clone()));
                        if !self.immutable_state {
                            // Mutable state (e.g. running group-by
                            // aggregates): migrate the moved keys' state
                            // once every upstream worker has emitted the
                            // new epoch's marker — the §3.5.3 safe point.
                            self.pending_sbk_moves.push((self.epoch, skewed, h, keys));
                        }
                    } else {
                        // Nothing movable: drop back to base routing.
                        self.push_route(
                            ctx,
                            skewed,
                            h,
                            ShareMode::SplitRecords { num: 1, den: SBR_DEN },
                        );
                    }
                }
            }
        }
        let m = &mut self.mitigations[mi];
        m.phase = Phase::Rebalance;
        m.iterations += 1;
        // New iteration → fresh estimation sample (§3.4.3.1).
        self.estimators[skewed].reset();
        for h in helpers {
            self.estimators[h].reset();
        }
        let mut rep = self.report.lock().unwrap();
        rep.phase2.push((ctx.started.elapsed().as_secs_f64(), skewed));
        rep.iterations += 1;
    }

    /// Start a brand-new mitigation for (skewed, helpers).
    fn start_mitigation(&mut self, ctx: &PluginCtx, skewed: usize, helpers: Vec<usize>) {
        let t = ctx.started.elapsed().as_secs_f64();
        self.report
            .lock()
            .unwrap()
            .mitigations
            .push((t, skewed, helpers.clone()));
        if self.immutable_state {
            // Fig. 3.2(b–d): replicate the skewed worker's state to
            // each helper, then change the partitioning on ack.
            let mut outstanding = 0;
            for &h in &helpers {
                let tid = self.next_transfer;
                self.next_transfer += 1;
                self.pending_transfers.push((tid, self.mitigations.len()));
                ctx.send_control(
                    WorkerId::new(self.target_op, skewed),
                    ControlMessage::SendState {
                        to: WorkerId::new(self.target_op, h),
                        keys: None,
                        transfer_id: tid,
                        replicate: true,
                    },
                );
                outstanding += 1;
            }
            self.mitigations.push(Mitigation {
                skewed,
                helpers,
                phase: Phase::AwaitState { outstanding },
                iterations: 0,
            });
        } else {
            // Mutable state: the scattered-state merge (SBR, §3.5.4)
            // or marker-synchronized key moves (SBK, §3.5.3) happen on
            // the data plane; start phase 1 immediately.
            if self.phase1_enabled {
                for &h in &helpers {
                    self.push_route(ctx, skewed, h, ShareMode::CatchUpAll);
                }
            }
            self.mitigations.push(Mitigation {
                skewed,
                helpers,
                phase: Phase::CatchUp,
                iterations: 0,
            });
            if !self.phase1_enabled {
                let mi = self.mitigations.len() - 1;
                self.start_phase2(ctx, mi);
            }
        }
    }
}

impl CoordPlugin for ReshapePlugin {
    fn name(&self) -> &str {
        "reshape"
    }

    fn period(&self) -> Duration {
        Duration::from_millis(20)
    }

    fn tick(&mut self, ctx: &PluginCtx) {
        let elapsed = ctx.started.elapsed();
        if elapsed.as_millis() < ctx.config.reshape_initial_delay_ms as u128 {
            return;
        }
        if self.tau.is_nan() {
            self.tau = ctx.config.reshape_tau;
        }
        let n = ctx.workers_of(self.target_op);
        if self.estimators.len() != n {
            // First tick, or an elastic scale changed the protected
            // operator's parallelism: every per-worker series and every
            // mitigation references the old worker set, so start over
            // against the new one (the scale fence already cleared the
            // overlay routes and re-hashed the state).
            self.mitigations.clear();
            self.pending_transfers.clear();
            self.pending_sbk_moves.clear();
            self.estimators =
                vec![MeanEstimator::new(ctx.config.reshape_sample_window); n];
            self.last_base = vec![0; n];
            for i in 0..n {
                if let Some(g) = ctx.gauges_of(WorkerId::new(self.target_op, i)) {
                    self.last_base[i] = g.base_received.load(Ordering::Relaxed);
                    if self.approach == Approach::SplitByKeys {
                        // SBK needs the per-key distribution (§3.3.1).
                        g.track_keys.store(true, Ordering::Relaxed);
                    }
                }
            }
        }
        self.ticks += 1;
        let loads = self.workloads(ctx);
        // Feed estimators with base-receipt deltas.
        for i in 0..n {
            if let Some(g) = ctx.gauges_of(WorkerId::new(self.target_op, i)) {
                let cur = g.base_received.load(Ordering::Relaxed);
                let delta = (cur - self.last_base[i]) as f64;
                self.last_base[i] = cur;
                self.estimators[i].observe(delta);
            }
        }
        // Record timeline.
        {
            let t = elapsed.as_secs_f64();
            let mut rep = self.report.lock().unwrap();
            for i in 0..n {
                let recv = ctx
                    .gauges_of(WorkerId::new(self.target_op, i))
                    .map(|g| g.received.load(Ordering::Relaxed))
                    .unwrap_or(0);
                rep.timeline.push((t, i, recv, loads[i]));
            }
            rep.tau_history.push((t, self.tau));
        }
        let (eta, tau) = self.thresholds(ctx);

        // Advance active mitigations.
        for mi in 0..self.mitigations.len() {
            match self.mitigations[mi].phase {
                Phase::AwaitState { .. } => {}
                Phase::CatchUp => {
                    let skewed = self.mitigations[mi].skewed;
                    let caught_up = self.mitigations[mi]
                        .helpers
                        .iter()
                        .all(|&h| loads[h] >= loads[skewed] - (tau / 4.0).max(8.0));
                    if caught_up {
                        self.start_phase2(ctx, mi);
                    }
                }
                Phase::Rebalance => {
                    // Divergence → another iteration (§3.4.3.1).
                    let skewed = self.mitigations[mi].skewed;
                    let diverged = self.mitigations[mi]
                        .helpers
                        .iter()
                        .any(|&h| loads[skewed] >= eta && loads[skewed] - loads[h] >= tau);
                    if diverged {
                        // Re-enter catch-up briefly, then re-estimate.
                        let helpers = self.mitigations[mi].helpers.clone();
                        for &h in &helpers {
                            self.push_route(ctx, skewed, h, ShareMode::CatchUpAll);
                        }
                        self.mitigations[mi].phase = Phase::CatchUp;
                    }
                }
            }
        }

        // Dynamic τ (Algorithm 1) on the widest unmitigated gap.
        if ctx.config.reshape_dynamic_tau
            && ctx.config.reshape_metric == crate::config::WorkloadMetric::QueueSize
            && self.tau_adjustments < ctx.config.reshape_max_tau_adjust
        {
            let mitigated: Vec<usize> = self
                .mitigations
                .iter()
                .flat_map(|m| std::iter::once(m.skewed).chain(m.helpers.iter().copied()))
                .collect();
            let free: Vec<usize> =
                (0..n).filter(|i| !mitigated.contains(i)).collect();
            if free.len() >= 2 {
                let hi = *free
                    .iter()
                    .max_by(|&&a, &&b| loads[a].partial_cmp(&loads[b]).unwrap())
                    .unwrap();
                let lo = *free
                    .iter()
                    .min_by(|&&a, &&b| loads[a].partial_cmp(&loads[b]).unwrap())
                    .unwrap();
                let gap = loads[hi] - loads[lo];
                let eps = self.estimators[hi].standard_error();
                match adjust_tau(
                    self.tau,
                    gap,
                    eps,
                    ctx.config.reshape_eps_range,
                    ctx.config.reshape_tau_step,
                ) {
                    TauDecision::Increase(t) => {
                        self.tau = t;
                        self.tau_adjustments += 1;
                    }
                    TauDecision::Decrease(t) => {
                        self.tau = t.max(1.0);
                        self.tau_adjustments += 1;
                    }
                    TauDecision::Keep => {}
                }
            }
        }

        // Detect new skew.
        let busy: Vec<usize> = self
            .mitigations
            .iter()
            .flat_map(|m| std::iter::once(m.skewed).chain(m.helpers.iter().copied()))
            .collect();
        let (eta, tau) = self.thresholds(ctx);
        let found = detector::detect(
            &loads,
            &busy,
            eta,
            tau,
            ctx.config.reshape_max_helpers,
        );
        for (skewed, helpers) in found.pairs {
            self.start_mitigation(ctx, skewed, helpers);
        }
    }

    fn on_event(&mut self, ev: &WorkerEvent, ctx: &PluginCtx) {
        if let WorkerEvent::MarkerAligned { worker, epoch } = ev {
            // The skewed worker has seen the epoch marker from every
            // upstream sender: no more pre-epoch tuples can arrive, so
            // the moved keys' mutable state can migrate safely (§3.5.3).
            if worker.op == self.target_op {
                let due: Vec<usize> = self
                    .pending_sbk_moves
                    .iter()
                    .enumerate()
                    .filter(|(_, (e, s, _, _))| *e <= *epoch && *s == worker.idx)
                    .map(|(i, _)| i)
                    .collect();
                for i in due.into_iter().rev() {
                    let (_, skewed, helper, keys) = self.pending_sbk_moves.swap_remove(i);
                    let tid = self.next_transfer;
                    self.next_transfer += 1;
                    ctx.send_control(
                        WorkerId::new(self.target_op, skewed),
                        ControlMessage::SendState {
                            to: WorkerId::new(self.target_op, helper),
                            keys: Some(keys),
                            transfer_id: tid,
                            replicate: false, // mutable state MOVES
                        },
                    );
                }
            }
        }
        if let WorkerEvent::StateApplied { transfer_id, .. } = ev {
            let t = ctx.started.elapsed().as_secs_f64();
            self.report.lock().unwrap().transfers.push((t, *transfer_id));
            if let Some(pos) = self
                .pending_transfers
                .iter()
                .position(|(tid, _)| tid == transfer_id)
            {
                let (_, mi) = self.pending_transfers.swap_remove(pos);
                if let Some(m) = self.mitigations.get_mut(mi) {
                    if let Phase::AwaitState { outstanding } = &mut m.phase {
                        *outstanding -= 1;
                        if *outstanding == 0 {
                            // Fig. 3.2(e,f): all helpers have the
                            // state; change the partitioning logic.
                            let skewed = m.skewed;
                            let helpers = m.helpers.clone();
                            m.phase = Phase::CatchUp;
                            if self.phase1_enabled {
                                for &h in &helpers {
                                    self.push_route(ctx, skewed, h, ShareMode::CatchUpAll);
                                }
                            } else {
                                // Fig. 3.18/3.19 ablation: phase 2 only.
                                self.start_phase2(ctx, mi);
                            }
                        }
                    }
                }
            }
        }
    }
}

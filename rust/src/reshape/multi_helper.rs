//! Multiple helpers per skewed worker (§3.6.2): the χ = min(LRmax, F)
//! trade-off between load reduction and state-migration cost.
//!
//! Adding helpers raises the ideal load reduction
//! `LRmax = (f_S − avg) · T` but also raises the migration time M,
//! shrinking `F = (L − M·t) · f̂_S` — the future tuples left to
//! actually rebalance. The chosen helper set is the one *right before*
//! χ starts decreasing (Fig. 3.13).

/// Maximum load reduction with helper set `helpers` (workload
/// fractions) for a skewed worker with fraction `fs`, over `total`
/// future tuples (§3.6.2).
pub fn lr_max(fs: f64, helpers: &[f64], total: f64) -> f64 {
    let n = helpers.len() as f64;
    if n == 0.0 {
        return 0.0;
    }
    let avg = (fs + helpers.iter().sum::<f64>()) / (n + 1.0);
    (fs - avg) * total
}

/// Future tuples of S left after migration: F = (L − M·t)·f̂_S.
pub fn future_after_migration(l: f64, m: f64, t: f64, fs: f64) -> f64 {
    ((l - m * t) * fs).max(0.0)
}

/// Pick the helper count maximizing χ = min(LRmax, F).
///
/// * `fs` — skewed worker's workload fraction;
/// * `candidates` — candidate helpers' workload fractions, best
///   (lowest) first;
/// * `l` — future tuples to be processed by the operator at detection;
/// * `migration_time(k)` — estimated migration time with k helpers;
/// * `t` — operator throughput.
///
/// Returns (helper count, χ at that count).
pub fn choose_helper_count(
    fs: f64,
    candidates: &[f64],
    l: f64,
    migration_time: impl Fn(usize) -> f64,
    t: f64,
) -> (usize, f64) {
    let mut best = (0usize, 0.0f64);
    let mut prev_chi = 0.0f64;
    for k in 1..=candidates.len() {
        let lrm = lr_max(fs, &candidates[..k], l);
        let f = future_after_migration(l, migration_time(k), t, fs);
        let chi = lrm.min(f);
        if chi > prev_chi {
            best = (k, chi);
            prev_chi = chi;
        } else {
            // χ started decreasing: stop (Fig. 3.13's rule).
            break;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lr_max_grows_with_cheap_helpers() {
        let one = lr_max(0.5, &[0.1], 1000.0);
        let two = lr_max(0.5, &[0.1, 0.1], 1000.0);
        assert!(two > one);
    }

    #[test]
    fn lr_max_zero_without_helpers() {
        assert_eq!(lr_max(0.5, &[], 1000.0), 0.0);
    }

    #[test]
    fn future_shrinks_with_migration_time() {
        let f1 = future_after_migration(1000.0, 1.0, 100.0, 0.5);
        let f2 = future_after_migration(1000.0, 5.0, 100.0, 0.5);
        assert!(f2 < f1);
        assert_eq!(future_after_migration(10.0, 1.0, 100.0, 0.5), 0.0);
    }

    #[test]
    fn chooses_knee_of_chi() {
        // Cheap helpers but migration cost grows linearly; at some
        // count the F term dominates and χ drops.
        let candidates = vec![0.05; 8];
        let (k, chi) = choose_helper_count(
            0.6,
            &candidates,
            1000.0,
            |k| 2.0 * k as f64, // 2 time units per helper
            100.0,
        );
        assert!(k >= 1 && k < 8, "expected an interior knee, got {k}");
        assert!(chi > 0.0);
        // χ at k+1 must not beat χ at k (the stopping rule).
        let lrm_next = lr_max(0.6, &candidates[..k + 1], 1000.0);
        let f_next = future_after_migration(1000.0, 2.0 * (k + 1) as f64, 100.0, 0.6);
        assert!(lrm_next.min(f_next) <= chi + 1e-9);
    }

    #[test]
    fn single_helper_when_migration_free() {
        // With zero migration cost, χ = LRmax which keeps growing; we
        // take all candidates.
        let candidates = vec![0.0, 0.0, 0.0];
        let (k, _) = choose_helper_count(0.9, &candidates, 100.0, |_| 0.0, 10.0);
        assert_eq!(k, 3);
    }
}

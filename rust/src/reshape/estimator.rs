//! Workload estimation (§3.3.2, §3.4): the mean-model estimator ψ with
//! its standard error ε.
//!
//! Reshape samples each worker's incoming workload (tuples received per
//! metric period, from the *base* partitioning — i.e. what the worker
//! would receive without mitigation) and predicts the near-future rate
//! as the sample mean. The standard error of the mean-model prediction
//! is ε = d·√(1 + 1/n) (§3.4.3.2), which Algorithm 1 compares against
//! the acceptable range [ε_l, ε_u] to adapt τ.

/// Sliding-window mean-model estimator for one worker's input rate.
#[derive(Clone, Debug)]
pub struct MeanEstimator {
    window: usize,
    samples: Vec<f64>,
}

impl MeanEstimator {
    pub fn new(window: usize) -> MeanEstimator {
        MeanEstimator { window: window.max(2), samples: Vec::new() }
    }

    /// Record one observation (tuples received in the last period).
    pub fn observe(&mut self, v: f64) {
        self.samples.push(v);
        if self.samples.len() > self.window {
            self.samples.remove(0);
        }
    }

    /// Drop history (a new mitigation iteration starts a fresh sample,
    /// §3.4.3.1: "uses the sample collected since t₂").
    pub fn reset(&mut self) {
        self.samples.clear();
    }

    pub fn n(&self) -> usize {
        self.samples.len()
    }

    /// Predicted future rate (mean model, [111] in the paper).
    pub fn predict(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    /// Sample standard deviation d.
    pub fn stddev(&self) -> f64 {
        let n = self.samples.len();
        if n < 2 {
            return f64::INFINITY;
        }
        let mean = self.predict();
        let var = self
            .samples
            .iter()
            .map(|x| (x - mean) * (x - mean))
            .sum::<f64>()
            / (n as f64 - 1.0);
        var.sqrt()
    }

    /// Standard error of the mean-model prediction:
    /// ε = d·√(1 + 1/n) (§3.4.3.2).
    pub fn standard_error(&self) -> f64 {
        let n = self.samples.len();
        if n < 2 {
            return f64::INFINITY;
        }
        self.stddev() * (1.0 + 1.0 / n as f64).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_prediction() {
        let mut e = MeanEstimator::new(8);
        for v in [10.0, 12.0, 14.0] {
            e.observe(v);
        }
        assert_eq!(e.predict(), 12.0);
    }

    #[test]
    fn window_slides() {
        let mut e = MeanEstimator::new(3);
        for v in [100.0, 1.0, 1.0, 1.0] {
            e.observe(v);
        }
        assert_eq!(e.predict(), 1.0);
        assert_eq!(e.n(), 3);
    }

    #[test]
    fn error_decreases_with_sample_size() {
        // Same alternating signal; more samples → smaller ε.
        let mut small = MeanEstimator::new(64);
        let mut large = MeanEstimator::new(64);
        for i in 0..4 {
            small.observe(if i % 2 == 0 { 10.0 } else { 12.0 });
        }
        for i in 0..32 {
            large.observe(if i % 2 == 0 { 10.0 } else { 12.0 });
        }
        assert!(large.standard_error() < small.standard_error());
    }

    #[test]
    fn error_infinite_until_two_samples() {
        let mut e = MeanEstimator::new(8);
        assert!(e.standard_error().is_infinite());
        e.observe(1.0);
        assert!(e.standard_error().is_infinite());
        e.observe(1.0);
        assert!(e.standard_error().is_finite());
    }

    #[test]
    fn constant_signal_zero_error() {
        let mut e = MeanEstimator::new(8);
        for _ in 0..5 {
            e.observe(7.0);
        }
        assert_eq!(e.standard_error(), 0.0);
    }

    #[test]
    fn reset_clears() {
        let mut e = MeanEstimator::new(8);
        e.observe(5.0);
        e.reset();
        assert_eq!(e.n(), 0);
        assert_eq!(e.predict(), 0.0);
    }
}

//! Skew detection and helper selection (§3.2.1).
//!
//! The skew test between a loaded worker L and a candidate helper C:
//!
//! ```text
//! φ_L ≥ η            (3.1)  — L is actually burdened
//! φ_L − φ_C ≥ τ      (3.2)  — the gap is big enough to act on
//! ```
//!
//! Helper selection: "the helper candidate with the lowest workload
//! that has not been assigned to any other overloaded worker".

/// Result of a full skew scan over an operator's workers.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SkewTestResult {
    /// (skewed worker idx, chosen helper idxs) pairs, heaviest first.
    pub pairs: Vec<(usize, Vec<usize>)>,
}

/// Inequalities (3.1)+(3.2) for one (L, C) pair.
pub fn skew_test(phi_l: f64, phi_c: f64, eta: f64, tau: f64) -> bool {
    phi_l >= eta && phi_l - phi_c >= tau
}

/// Scan all workers; returns skewed→helpers assignments.
///
/// * `loads[i]` — current workload φ of worker i;
/// * `excluded` — workers already acting as skewed or helper (an
///   in-flight mitigation owns them);
/// * `helpers_per_skewed` — helpers to allot per skewed worker (1 in
///   the base design; §3.6.2 generalizes).
pub fn detect(
    loads: &[f64],
    excluded: &[usize],
    eta: f64,
    tau: f64,
    helpers_per_skewed: usize,
) -> SkewTestResult {
    let mut result = SkewTestResult::default();
    let mut taken: Vec<usize> = excluded.to_vec();
    // Consider the most loaded workers first.
    let mut by_load: Vec<usize> = (0..loads.len()).collect();
    by_load.sort_by(|&a, &b| loads[b].partial_cmp(&loads[a]).unwrap());
    for &l in &by_load {
        if taken.contains(&l) {
            continue;
        }
        // Candidate helpers: lowest workload first, unassigned.
        let mut cands: Vec<usize> = (0..loads.len())
            .filter(|&c| c != l && !taken.contains(&c))
            .collect();
        cands.sort_by(|&a, &b| loads[a].partial_cmp(&loads[b]).unwrap());
        let mut helpers = Vec::new();
        for &c in cands.iter().take(helpers_per_skewed) {
            if skew_test(loads[l], loads[c], eta, tau) {
                helpers.push(c);
            }
        }
        if !helpers.is_empty() {
            taken.push(l);
            taken.extend(helpers.iter().copied());
            result.pairs.push((l, helpers));
        }
    }
    result
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inequalities_enforced() {
        // Below η: not skewed no matter the gap.
        assert!(!skew_test(50.0, 0.0, 100.0, 10.0));
        // Above η but gap < τ.
        assert!(!skew_test(150.0, 100.0, 100.0, 100.0));
        // Both hold.
        assert!(skew_test(250.0, 100.0, 100.0, 100.0));
    }

    #[test]
    fn picks_lowest_loaded_helper() {
        let loads = vec![500.0, 10.0, 40.0, 30.0];
        let r = detect(&loads, &[], 100.0, 100.0, 1);
        assert_eq!(r.pairs, vec![(0, vec![1])]);
    }

    #[test]
    fn helper_not_shared_between_skewed_workers() {
        let loads = vec![500.0, 480.0, 10.0, 20.0];
        let r = detect(&loads, &[], 100.0, 100.0, 1);
        assert_eq!(r.pairs.len(), 2);
        assert_eq!(r.pairs[0], (0, vec![2]));
        assert_eq!(r.pairs[1], (1, vec![3]));
    }

    #[test]
    fn excluded_workers_skipped() {
        let loads = vec![500.0, 10.0, 400.0, 20.0];
        // Worker 0 and 1 already mitigated.
        let r = detect(&loads, &[0, 1], 100.0, 100.0, 1);
        assert_eq!(r.pairs, vec![(2, vec![3])]);
    }

    #[test]
    fn no_detection_below_threshold() {
        let loads = vec![100.0, 90.0, 95.0];
        let r = detect(&loads, &[], 100.0, 100.0, 1);
        assert!(r.pairs.is_empty());
    }

    #[test]
    fn multi_helper_allocation() {
        let loads = vec![900.0, 10.0, 20.0, 30.0];
        let r = detect(&loads, &[], 100.0, 100.0, 2);
        assert_eq!(r.pairs, vec![(0, vec![1, 2])]);
    }

    #[test]
    fn helper_must_pass_gap_test() {
        // Second candidate's gap is below τ → only one helper chosen.
        let loads = vec![300.0, 10.0, 250.0];
        let r = detect(&loads, &[], 100.0, 100.0, 2);
        assert_eq!(r.pairs, vec![(0, vec![1])]);
    }
}

//! **Reshape** (Ch. 3): adaptive, result-aware partitioning-skew
//! handling built on the engine's fast control messages.
//!
//! The controller periodically collects workload metrics from the
//! protected operator's workers (§3.2.1), runs the skew test
//! (φ_L ≥ η and φ_L − φ_C ≥ τ), picks helpers, migrates state, and
//! changes the upstream partitioning logic in **two phases** (§3.3.2):
//! phase 1 lets the helper catch up with the skewed worker's backlog;
//! phase 2 rebalances future input using the [`estimator`]'s workload
//! predictions, iterating when predictions drift (§3.4) and adjusting
//! the detection threshold τ from the estimator's standard error
//! (Algorithm 1).
//!
//! [`baselines`] reimplements the two comparison systems of §3.7 —
//! Flux (SBK mini-partition moves, no key splitting) and Flow-Join
//! (one-shot heavy-hitter detection, static 50/50 split).

pub mod estimator;
pub mod detector;
pub mod adaptive;
pub mod multi_helper;
pub mod plugin;
pub mod baselines;

pub use detector::{skew_test, SkewTestResult};
pub use estimator::MeanEstimator;
pub use plugin::{Approach, ReshapePlugin, ReshapeReport};

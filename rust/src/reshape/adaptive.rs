//! Dynamic adjustment of the skew-detection threshold τ (§3.4.3.2,
//! Algorithm 1) and the state-migration-time correction τ′ (§3.6.1).

/// Outcome of one Algorithm-1 evaluation.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum TauDecision {
    /// Keep τ as is.
    Keep,
    /// Raise τ for the next iteration (error too high: need a bigger
    /// sample before trusting the estimator).
    Increase(f64),
    /// Lower τ to the current workload gap and mitigate right away
    /// (error already low; waiting longer risks running out of future
    /// tuples).
    Decrease(f64),
}

/// Algorithm 1: adjust τ given the current gap (φ_S − φ_H), the
/// estimator's standard error ε, the acceptable range [ε_l, ε_u], and
/// the increment step.
pub fn adjust_tau(
    tau: f64,
    gap: f64,
    eps: f64,
    eps_range: (f64, f64),
    step: f64,
) -> TauDecision {
    let (eps_l, eps_u) = eps_range;
    if gap >= tau && eps > eps_u {
        // Skew test passes but the prediction is too noisy: a larger τ
        // gives the next iteration a bigger sample (line 5–6).
        TauDecision::Increase(tau + step)
    } else if gap < tau && eps < eps_l {
        // Error is already low; start mitigation at the current gap
        // instead of waiting for τ (line 7–8).
        TauDecision::Decrease(gap.max(0.0))
    } else {
        TauDecision::Keep
    }
}

/// τ′ correction when state migration takes significant time (§3.6.1):
/// detect earlier so the migration *ends* when the gap reaches τₙ.
///
/// τ′ₙ = τₙ − (f̂_S − f̂_H) · t · M
///
/// * `fs`, `fh` — predicted workload fractions of skewed and helper;
/// * `t` — operator throughput (tuples per unit time);
/// * `m` — estimated state-migration time (same unit).
pub fn tau_with_migration(tau: f64, fs: f64, fh: f64, t: f64, m: f64) -> f64 {
    (tau - (fs - fh) * t * m).max(0.0)
}

/// Precondition for mitigation (§3.6.1): migrating is futile if it
/// takes longer than the remaining execution.
pub fn migration_worthwhile(est_migration_time: f64, est_time_left: f64) -> bool {
    est_migration_time < est_time_left
}

#[cfg(test)]
mod tests {
    use super::*;

    const RANGE: (f64, f64) = (98.0, 110.0);

    #[test]
    fn increase_when_noisy_and_skewed() {
        let d = adjust_tau(100.0, 150.0, 200.0, RANGE, 50.0);
        assert_eq!(d, TauDecision::Increase(150.0));
    }

    #[test]
    fn decrease_when_quiet_and_below_tau() {
        let d = adjust_tau(1000.0, 700.0, 50.0, RANGE, 50.0);
        assert_eq!(d, TauDecision::Decrease(700.0));
    }

    #[test]
    fn keep_when_error_in_range() {
        assert_eq!(adjust_tau(100.0, 150.0, 105.0, RANGE, 50.0), TauDecision::Keep);
        assert_eq!(adjust_tau(100.0, 50.0, 105.0, RANGE, 50.0), TauDecision::Keep);
    }

    #[test]
    fn keep_when_skewed_but_quiet() {
        // Gap ≥ τ and ε small: mitigation proceeds with current τ.
        assert_eq!(adjust_tau(100.0, 150.0, 10.0, RANGE, 50.0), TauDecision::Keep);
    }

    #[test]
    fn migration_correction_lowers_tau() {
        // fs=0.6, fh=0.1, t=100 tuples/s, M=2 s → correction = 100.
        assert_eq!(tau_with_migration(300.0, 0.6, 0.1, 100.0, 2.0), 200.0);
    }

    #[test]
    fn migration_correction_clamps_at_zero() {
        assert_eq!(tau_with_migration(50.0, 0.9, 0.0, 1000.0, 10.0), 0.0);
    }

    #[test]
    fn futile_migration_rejected() {
        assert!(!migration_worthwhile(10.0, 5.0));
        assert!(migration_worthwhile(1.0, 5.0));
    }
}

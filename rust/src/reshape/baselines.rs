//! Baseline skew handlers reimplemented for comparison (§3.7.1):
//!
//! * **Flux** [103] — adaptive SBK over pre-defined mini-partitions:
//!   on skew, whole keys move from the skewed worker to its helper;
//!   a single key can never be split, so a heavy-hitter-dominated
//!   worker barely improves (the Fig. 3.20 ~0.06 ratio).
//! * **Flow-Join** [100] — static SBR: sample the first `detect_ms`
//!   of input, mark heavy hitters, then split exactly 50% of their
//!   future tuples to the helper, once, with no further adaptation
//!   (so it overshoots when the distribution shifts — Fig. 3.24).

use crate::engine::controller::{CoordPlugin, PluginCtx};
use crate::engine::message::{ControlMessage, WorkerEvent, WorkerId};
use crate::engine::partitioner::{MitigationRoute, ShareMode};
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Flux: move whole (non-heaviest) keys from skewed workers to helpers.
pub struct FluxPlugin {
    target_op: usize,
    /// (skewed, helper) pairs already mitigated.
    mitigated: Vec<(usize, usize)>,
    /// Route installs deferred until the moved keys' state lands at the
    /// helper: transfer id → (skewed, helper, keys).
    pending: Vec<(u64, usize, usize, Vec<u64>)>,
    /// Chosen (skewed, helper) pairs, observable by harnesses.
    pairs: Arc<Mutex<Vec<(usize, usize)>>>,
    epoch: u64,
    initialized: bool,
}

impl FluxPlugin {
    pub fn new(target_op: usize) -> FluxPlugin {
        FluxPlugin {
            target_op,
            mitigated: Vec::new(),
            pending: Vec::new(),
            pairs: Arc::new(Mutex::new(Vec::new())),
            epoch: 0,
            initialized: false,
        }
    }

    /// Shared handle to the chosen (skewed, helper) pairs.
    pub fn pairs(&self) -> Arc<Mutex<Vec<(usize, usize)>>> {
        self.pairs.clone()
    }

    fn loads(&self, ctx: &PluginCtx) -> Vec<f64> {
        (0..ctx.workers_of(self.target_op))
            .map(|i| {
                let id = WorkerId::new(self.target_op, i);
                if ctx.completed.contains(&id) {
                    return 0.0;
                }
                ctx.gauges_of(id)
                    .map(|g| g.queued.load(Ordering::Relaxed).max(0) as f64)
                    .unwrap_or(0.0)
            })
            .collect()
    }
}

impl CoordPlugin for FluxPlugin {
    fn name(&self) -> &str {
        "flux"
    }

    fn period(&self) -> Duration {
        Duration::from_millis(20)
    }

    fn tick(&mut self, ctx: &PluginCtx) {
        // Track the key distribution from the start; only *act* after
        // the initial observation window (§3.7.1).
        if !self.initialized {
            self.initialized = true;
            for i in 0..ctx.workers_of(self.target_op) {
                if let Some(g) = ctx.gauges_of(WorkerId::new(self.target_op, i)) {
                    g.track_keys.store(true, Ordering::Relaxed);
                }
            }
        }
        if ctx.started.elapsed().as_millis()
            < ctx.config.reshape_initial_delay_ms as u128
        {
            return;
        }
        let loads = self.loads(ctx);
        let busy: Vec<usize> = self
            .mitigated
            .iter()
            .flat_map(|(s, h)| [*s, *h])
            .collect();
        let found = crate::reshape::detector::detect(
            &loads,
            &busy,
            ctx.config.reshape_eta,
            ctx.config.reshape_tau,
            1,
        );
        for (skewed, helpers) in found.pairs {
            let helper = helpers[0];
            // Move every key except the heaviest (Flux cannot split a
            // key; relocating the heavy hitter would just move the
            // hotspot).
            let id = WorkerId::new(self.target_op, skewed);
            let Some(g) = ctx.gauges_of(id) else { continue };
            let counts = g.key_counts.lock().unwrap();
            let mut items: Vec<(u64, u64)> =
                counts.iter().map(|(k, v)| (*k, *v)).collect();
            drop(counts);
            if items.len() < 2 {
                // Only the heavy hitter lives here: nothing movable.
                self.mitigated.push((skewed, helper));
                self.pairs.lock().unwrap().push((skewed, helper));
                continue;
            }
            items.sort_by_key(|(_, c)| std::cmp::Reverse(*c));
            let moved: Vec<u64> = items.iter().skip(1).map(|(k, _)| *k).collect();
            // Migrate the moved keys' state first (Flux moves
            // mini-partitions); the route flips on the helper's ack so
            // no probe tuple reaches the helper before its build rows.
            self.epoch += 1;
            ctx.send_control(
                id,
                ControlMessage::SendState {
                    to: WorkerId::new(self.target_op, helper),
                    keys: Some(moved.clone()),
                    transfer_id: self.epoch,
                    replicate: true,
                },
            );
            self.pending.push((self.epoch, skewed, helper, moved));
            self.mitigated.push((skewed, helper));
            self.pairs.lock().unwrap().push((skewed, helper));
        }
    }

    fn on_event(&mut self, ev: &WorkerEvent, ctx: &PluginCtx) {
        if let WorkerEvent::StateApplied { transfer_id, .. } = ev {
            if let Some(pos) = self.pending.iter().position(|(t, ..)| t == transfer_id) {
                let (_, skewed, helper, moved) = self.pending.swap_remove(pos);
                self.epoch += 1;
                for up in ctx.upstream_ops(self.target_op) {
                    ctx.broadcast(
                        up,
                        ControlMessage::UpdateRoute {
                            target_op: self.target_op,
                            route: MitigationRoute {
                                skewed,
                                helper,
                                mode: ShareMode::SplitKeys(moved.clone()),
                                epoch: self.epoch,
                            },
                        },
                    );
                }
            }
        }
    }
}

/// Flow-Join: one-shot heavy-hitter detection, then a static 50/50
/// record split of those keys to the helper.
pub struct FlowJoinPlugin {
    target_op: usize,
    /// Initial detection window (the paper sweeps 2/4/8 s; scaled here).
    detect_ms: u64,
    fired: bool,
    initialized: bool,
    epoch: u64,
    /// Deferred route install: (transfer id, skewed, helper, hh keys).
    pending: Option<(u64, usize, usize, Vec<u64>)>,
    /// Chosen (skewed, helper) pairs, observable by harnesses.
    pairs: Arc<Mutex<Vec<(usize, usize)>>>,
}

impl FlowJoinPlugin {
    pub fn new(target_op: usize, detect_ms: u64) -> FlowJoinPlugin {
        FlowJoinPlugin {
            target_op,
            detect_ms,
            fired: false,
            initialized: false,
            epoch: 0,
            pending: None,
            pairs: Arc::new(Mutex::new(Vec::new())),
        }
    }

    /// Shared handle to the chosen (skewed, helper) pairs.
    pub fn pairs(&self) -> Arc<Mutex<Vec<(usize, usize)>>> {
        self.pairs.clone()
    }
}

impl CoordPlugin for FlowJoinPlugin {
    fn name(&self) -> &str {
        "flow_join"
    }

    fn period(&self) -> Duration {
        Duration::from_millis(10)
    }

    fn tick(&mut self, ctx: &PluginCtx) {
        if !self.initialized {
            self.initialized = true;
            for i in 0..ctx.workers_of(self.target_op) {
                if let Some(g) = ctx.gauges_of(WorkerId::new(self.target_op, i)) {
                    g.track_keys.store(true, Ordering::Relaxed);
                }
            }
        }
        if self.fired || ctx.started.elapsed().as_millis() < self.detect_ms as u128 {
            return;
        }
        self.fired = true;
        // Identify the most loaded worker and its heavy-hitter keys
        // from the sample observed so far.
        let n = ctx.workers_of(self.target_op);
        let loads: Vec<f64> = (0..n)
            .map(|i| {
                ctx.gauges_of(WorkerId::new(self.target_op, i))
                    .map(|g| g.received.load(Ordering::Relaxed) as f64)
                    .unwrap_or(0.0)
            })
            .collect();
        let skewed = (0..n)
            .max_by(|&a, &b| loads[a].partial_cmp(&loads[b]).unwrap())
            .unwrap();
        let helper = (0..n)
            .min_by(|&a, &b| loads[a].partial_cmp(&loads[b]).unwrap())
            .unwrap();
        if skewed == helper {
            return;
        }
        let Some(g) = ctx.gauges_of(WorkerId::new(self.target_op, skewed)) else {
            return;
        };
        let counts = g.key_counts.lock().unwrap();
        let total: u64 = counts.values().sum();
        // Heavy hitter: > 20% of the worker's sample.
        let hh: Vec<u64> = counts
            .iter()
            .filter(|(_, c)| **c as f64 > total as f64 * 0.2)
            .map(|(k, _)| *k)
            .collect();
        drop(counts);
        if hh.is_empty() {
            return;
        }
        // Replicate build state for the heavy hitters first; the 50/50
        // record split flips on the helper's ack.
        self.epoch += 1;
        ctx.send_control(
            WorkerId::new(self.target_op, skewed),
            ControlMessage::SendState {
                to: WorkerId::new(self.target_op, helper),
                keys: Some(hh.clone()),
                transfer_id: self.epoch,
                replicate: true,
            },
        );
        self.pending = Some((self.epoch, skewed, helper, hh));
        self.pairs.lock().unwrap().push((skewed, helper));
    }

    fn on_event(&mut self, ev: &WorkerEvent, ctx: &PluginCtx) {
        if let WorkerEvent::StateApplied { transfer_id, .. } = ev {
            let matches = self
                .pending
                .as_ref()
                .map(|(tid, ..)| tid == transfer_id)
                .unwrap_or(false);
            if matches {
                let (_, skewed, helper, hh) = self.pending.take().unwrap();
                self.epoch += 1;
                for up in ctx.upstream_ops(self.target_op) {
                    ctx.broadcast(
                        up,
                        ControlMessage::UpdateRoute {
                            target_op: self.target_op,
                            route: MitigationRoute {
                                skewed,
                                helper,
                                // 50% of the heavy-hitter keys' tuples
                                // only — other keys keep their original
                                // worker (their state never moved).
                                mode: ShareMode::SplitRecordsKeys {
                                    keys: hh.clone(),
                                    num: 500,
                                    den: 1000,
                                },
                                epoch: self.epoch,
                            },
                        },
                    );
                }
            }
        }
    }
}

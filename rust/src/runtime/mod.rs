//! PJRT runtime: loads the AOT artifacts produced by
//! `python/compile/aot.py` (HLO **text** — see DESIGN.md; serialized
//! protos from jax ≥ 0.5 are rejected by xla_extension 0.5.1) and
//! executes them from the engine's hot path.
//!
//! Python runs only at build time (`make artifacts`); at run time the
//! rust binary is self-contained: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → compile once → execute many.

pub mod pjrt;

pub use pjrt::{InferenceHandle, InferenceServer, Tensor};

//! PJRT runtime: loads the AOT artifacts produced by
//! `python/compile/aot.py` (HLO **text** — see DESIGN.md; serialized
//! protos from jax ≥ 0.5 are rejected by xla_extension 0.5.1) and
//! executes them from the engine's hot path.
//!
//! Python runs only at build time (`make artifacts`); at run time the
//! rust binary is self-contained: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → compile once → execute many.
//!
//! The native path lives behind the `xla` cargo feature; the default
//! build ships a stub so the engine (and its ML operator plumbing)
//! compiles with zero external dependencies. See [`pjrt`].

pub mod pjrt;

pub use pjrt::{InferenceHandle, InferenceServer, PjrtError, Tensor};

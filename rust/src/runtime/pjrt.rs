//! The inference server: a dedicated thread owning the PJRT client and
//! compiled executables.
//!
//! The `xla` crate's wrappers hold raw pointers (not `Send`/`Sync`), so
//! executables cannot be shared across worker threads. Instead a single
//! server thread owns the client and an executable cache; ML-operator
//! workers talk to it through a cloneable [`InferenceHandle`] (request
//! channel + per-request reply channel). Model compilation happens once
//! per model name, on first use.
//!
//! The native backend is feature-gated: building with `--features xla`
//! selects the real PJRT path (which additionally requires adding the
//! `xla` crate to `[dependencies]` — it is not vendored, keeping the
//! default build offline and dependency-free). Without the feature this
//! module compiles a stub whose [`artifact_exists`] reports every model
//! as absent, so ML tests and benches skip gracefully instead of
//! failing.

/// Error from the inference runtime.
#[derive(Clone, Debug)]
pub struct PjrtError(pub String);

impl std::fmt::Display for PjrtError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "pjrt: {}", self.0)
    }
}

impl std::error::Error for PjrtError {}

/// Inference-runtime result type.
pub type Result<T> = std::result::Result<T, PjrtError>;

/// A host tensor crossing the server boundary.
#[derive(Clone, Debug)]
pub enum Tensor {
    I32(Vec<i32>, Vec<i64>),
    F32(Vec<f32>, Vec<i64>),
}

#[cfg(feature = "xla")]
pub use backend::{artifact_exists, InferenceHandle, InferenceServer};

#[cfg(not(feature = "xla"))]
pub use stub::{artifact_exists, InferenceHandle, InferenceServer};

/// Real PJRT backend (requires the `xla` crate; see module docs).
#[cfg(feature = "xla")]
mod backend {
    use super::{PjrtError, Result, Tensor};
    use std::collections::HashMap;
    use std::path::PathBuf;
    use std::sync::mpsc::{channel, Sender};

    fn err<E: std::fmt::Display>(ctx: &str, e: E) -> PjrtError {
        PjrtError(format!("{ctx}: {e}"))
    }

    impl Tensor {
        fn to_literal(&self) -> Result<xla::Literal> {
            Ok(match self {
                Tensor::I32(data, dims) => xla::Literal::vec1(data)
                    .reshape(dims)
                    .map_err(|e| err("reshape", e))?,
                Tensor::F32(data, dims) => xla::Literal::vec1(data)
                    .reshape(dims)
                    .map_err(|e| err("reshape", e))?,
            })
        }
    }

    struct Request {
        model: String,
        inputs: Vec<Tensor>,
        reply: Sender<Result<Vec<f32>>>,
    }

    /// Cloneable client handle to the inference server.
    #[derive(Clone)]
    pub struct InferenceHandle {
        tx: Sender<Request>,
    }

    impl InferenceHandle {
        /// Run `model` (loaded from `<artifacts>/<model>.hlo.txt`) on the
        /// inputs; returns the flattened f32 output of the first tuple
        /// element.
        pub fn run(&self, model: &str, inputs: Vec<Tensor>) -> Result<Vec<f32>> {
            let (rtx, rrx) = channel();
            self.tx
                .send(Request { model: model.to_string(), inputs, reply: rtx })
                .map_err(|_| PjrtError("inference server gone".into()))?;
            rrx.recv()
                .map_err(|_| PjrtError("inference server dropped reply".into()))?
        }
    }

    /// The server: spawn once per process (or per benchmark run).
    pub struct InferenceServer {
        handle: InferenceHandle,
        thread: Option<std::thread::JoinHandle<()>>,
    }

    impl InferenceServer {
        /// Start the server reading artifacts from `dir`.
        pub fn start(dir: &str) -> InferenceServer {
            let dir = PathBuf::from(dir);
            let (tx, rx) = channel::<Request>();
            let thread = std::thread::Builder::new()
                .name("pjrt-server".into())
                .spawn(move || {
                    let client = match xla::PjRtClient::cpu() {
                        Ok(c) => c,
                        Err(e) => {
                            // Fail every request with the construction error.
                            while let Ok(req) = rx.recv() {
                                let _ = req
                                    .reply
                                    .send(Err(err("PJRT client init failed", &e)));
                            }
                            return;
                        }
                    };
                    let mut cache: HashMap<String, xla::PjRtLoadedExecutable> =
                        HashMap::new();
                    while let Ok(req) = rx.recv() {
                        let result = serve(&client, &mut cache, &dir, &req);
                        let _ = req.reply.send(result);
                    }
                })
                .expect("spawn pjrt server");
            InferenceServer { handle: InferenceHandle { tx }, thread: Some(thread) }
        }

        pub fn handle(&self) -> InferenceHandle {
            self.handle.clone()
        }
    }

    impl Drop for InferenceServer {
        fn drop(&mut self) {
            // Close the request channel; the thread exits on recv error.
            let (tx, _) = channel();
            self.handle = InferenceHandle { tx };
            if let Some(t) = self.thread.take() {
                let _ = t.join();
            }
        }
    }

    fn serve(
        client: &xla::PjRtClient,
        cache: &mut HashMap<String, xla::PjRtLoadedExecutable>,
        dir: &std::path::Path,
        req: &Request,
    ) -> Result<Vec<f32>> {
        if !cache.contains_key(&req.model) {
            let path = dir.join(format!("{}.hlo.txt", req.model));
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| err(&format!("loading {}", path.display()), e))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| err(&format!("compiling {}", req.model), e))?;
            cache.insert(req.model.clone(), exe);
        }
        let exe = cache.get(&req.model).unwrap();
        let literals: Vec<xla::Literal> = req
            .inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| err("execute", e))?[0][0]
            .to_literal_sync()
            .map_err(|e| err("to_literal", e))?;
        // aot.py lowers with return_tuple=True → unwrap the 1-tuple.
        let out = result.to_tuple1().map_err(|e| err("to_tuple1", e))?;
        out.to_vec::<f32>().map_err(|e| err("to_vec", e))
    }

    /// Whether the artifacts directory has a given model (tests skip
    /// gracefully when `make artifacts` has not run).
    pub fn artifact_exists(dir: &str, model: &str) -> bool {
        PathBuf::from(dir).join(format!("{model}.hlo.txt")).exists()
    }
}

/// Stub backend for the default (offline, no-`xla`) build: the server
/// starts, but every request fails and no artifact is ever reported as
/// runnable — callers that gate on [`artifact_exists`] skip cleanly.
#[cfg(not(feature = "xla"))]
mod stub {
    use super::{PjrtError, Result, Tensor};

    /// Cloneable client handle to the (stub) inference server.
    #[derive(Clone, Default)]
    pub struct InferenceHandle;

    impl InferenceHandle {
        /// Always fails: there is no compiled-in PJRT backend.
        pub fn run(&self, model: &str, _inputs: Vec<Tensor>) -> Result<Vec<f32>> {
            Err(PjrtError(format!(
                "PJRT backend not compiled in (build with --features xla); \
                 cannot run model {model}"
            )))
        }
    }

    /// Stub server: hands out failing handles.
    #[derive(Default)]
    pub struct InferenceServer {
        handle: InferenceHandle,
    }

    impl InferenceServer {
        pub fn start(_dir: &str) -> InferenceServer {
            InferenceServer::default()
        }

        pub fn handle(&self) -> InferenceHandle {
            self.handle.clone()
        }
    }

    /// No backend → no artifact is runnable; gated tests and benches
    /// skip.
    pub fn artifact_exists(_dir: &str, _model: &str) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// End-to-end smoke test against the classifier artifact; skipped
    /// when artifacts have not been built (always skipped on the stub
    /// backend, whose `artifact_exists` is constantly false).
    #[test]
    fn classifier_artifact_runs() {
        let dir = "artifacts";
        if !artifact_exists(dir, "classifier") {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let server = InferenceServer::start(dir);
        let h = server.handle();
        // Shapes must match python/compile/model.py: tokens i32[B, T].
        let (b, t) = (crate::operators::ml_infer::BATCH, crate::operators::ml_infer::TOKENS);
        let tokens = vec![1i32; b * t];
        let out = h
            .run("classifier", vec![Tensor::I32(tokens, vec![b as i64, t as i64])])
            .expect("inference");
        assert_eq!(out.len(), b * crate::operators::ml_infer::CLASSES);
        assert!(out.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn missing_model_errors_cleanly() {
        let server = InferenceServer::start("artifacts");
        let h = server.handle();
        let err = h.run("no_such_model", vec![Tensor::F32(vec![0.0], vec![1])]);
        assert!(err.is_err());
    }

    #[test]
    fn pjrt_error_displays_context() {
        let e = PjrtError("boom".into());
        assert!(format!("{e}").contains("boom"));
    }
}

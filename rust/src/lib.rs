//! # texera-amber
//!
//! Reproduction of *"Towards Interactive, Adaptive and Result-aware Big
//! Data Analytics"* (A. Kumar, UC Irvine, 2022) as a three-layer
//! rust + JAX + Pallas stack.
//!
//! The crate contains three systems layered on one pipelined dataflow
//! engine:
//!
//! * [`engine`] — **Amber** (Ch. 2): an actor-style parallel dataflow
//!   engine with a fast control-message path enabling sub-second
//!   pause/resume, operator investigation/modification at runtime,
//!   local & global conditional breakpoints, and fault tolerance via
//!   checkpoints + a control-replay log. The data plane is
//!   **batch-at-a-time and columnar**: tuples travel in shared
//!   [`tuple::TupleBatch`]es (zero-copy on slice and fan-out) whose
//!   storage is a struct-of-arrays [`column::ColumnSet`] of typed
//!   vectors — hashing, predicates, projections and scatter gathers
//!   run column-at-a-time over contiguous `i64`/`f64`/string vectors
//!   ([`column`]), with a cached row view materialized lazily for
//!   unconverted paths. Operators process chunks through
//!   [`engine::Operator::process_batch`], the exchange ships the
//!   sender's memoized hash column alongside each batch so receivers
//!   never re-hash, and the worker re-checks the control flag between
//!   chunks of `ctrl_check_interval` tuples — so the paper's §2.4
//!   control semantics (sub-second pause, exact breakpoints,
//!   replayable positions) are preserved while per-tuple dispatch,
//!   routing and clone costs amortize across the batch.
//! * [`reshape`] — **Reshape** (Ch. 3): adaptive, result-aware
//!   partitioning-skew mitigation built on the engine's control messages.
//! * [`maestro`] — **Maestro** (Ch. 4): result-aware, **elastic**
//!   region scheduling — materialization-choice enumeration and a
//!   worker-aware first-response-time cost model pick a plan under a
//!   cluster-wide worker budget, and observed statistics re-plan the
//!   remaining regions' worker counts between region activations
//!   (applied through the engine's fenced scaling).
//!
//! * [`service`] — the **multi-tenant serving layer** (Ch. 1's service
//!   setting): an [`service::EngineService`] admits many concurrent
//!   workflow submissions onto one shared engine — bounded admission
//!   queue with per-tenant quotas, priority bands with round-robin
//!   fairness, a *global* worker budget arbitrated across workflows by
//!   the same greedy marginal-gain allocator Maestro uses per region,
//!   pause-fence preemption of batch jobs under interactive load, and
//!   cross-workflow result reuse keyed on structural plan fingerprints.
//!
//! Supporting substrates: [`operators`] (relational + ML operator
//! library), [`workloads`] (synthetic TPC-H/DSB/tweet generators),
//! [`batch`] (a stage-by-stage comparator engine standing in for Spark),
//! [`runtime`] (PJRT loader for the AOT-compiled JAX/Pallas artifacts),
//! and [`metrics`]/[`util`] utilities.
//!
//! A chapter-by-chapter map of the dissertation onto these modules —
//! including the full region-scheduling lifecycle walkthrough
//! (enumerate → cost → deploy dormant → activate → observe → re-plan →
//! scale) with pointers into the code — lives in `docs/ARCHITECTURE.md`
//! at the repository root; the perf-trajectory file the benches write
//! is documented in `docs/BENCH.md`.

pub mod util;
pub mod tuple;
pub mod column;
pub mod config;
pub mod workloads;
pub mod engine;
pub mod operators;
pub mod reshape;
pub mod maestro;
pub mod batch;
pub mod runtime;
pub mod metrics;
pub mod flows;
pub mod service;

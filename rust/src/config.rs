//! Engine and experiment configuration.
//!
//! One flat struct with the paper's tunables, grouped by chapter:
//! batching (§2.3.3), control-message expedition (§2.4.2), breakpoint
//! waiting threshold τ (§2.5.3), Reshape's η/τ skew thresholds and
//! estimator range (§3.2, §3.4), and Maestro's cost-model constants
//! (§4.5.3). Defaults follow the paper's experimental settings.

/// Which workload metric Reshape reads (Fig. 3.27 shows the framework is
/// metric-agnostic: the Amber port used queue size, the Flink port used
/// `busyTimeMsPerSecond`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WorkloadMetric {
    /// Unprocessed input-queue size (Amber implementation, §3.2.1).
    QueueSize,
    /// Fraction of time busy in the last window (Flink implementation,
    /// §3.7.12).
    BusyTime,
}

/// Global engine + experiment configuration.
#[derive(Clone, Debug)]
pub struct Config {
    // ---- engine (Ch. 2) ----
    /// Tuples per data message ("The batch size used in data messages was
    /// 400 unless otherwise stated", §2.7.1).
    pub batch_size: usize,
    /// Bounded capacity (in messages) of each worker's data queue;
    /// senders block when full (congestion control, §2.3.3).
    pub data_queue_cap: usize,
    /// How many tuples the DP loop processes between checks of the
    /// control flag (1 = the paper's per-iteration check, §2.4.3).
    /// This is also the chunk length handed to `process_batch`, so it
    /// bounds both pause latency and the span over which per-tuple
    /// overheads amortize. The worker drops to single-tuple stepping
    /// while breakpoint targets or replay records are armed, keeping
    /// their semantics exact at any interval.
    pub ctrl_check_interval: usize,
    /// Principal's waiting threshold τ for global breakpoints, in ms
    /// (§2.5.3, Fig. 2.13).
    pub breakpoint_tau_ms: u64,
    /// Artificial control-message delivery delay in ms (0 = none);
    /// used by the Fig. 3.21 experiment.
    pub ctrl_delay_ms: u64,
    /// Enable the fault-tolerance control-replay log (§2.6.2). Also
    /// the master switch for *automatic* replay-based recovery: with
    /// the log on, a declared worker failure triggers restore +
    /// replay; with it off, a failure aborts the run cleanly with
    /// [`crate::engine::ExecError::Unsupervised`].
    pub ft_log: bool,
    /// Declare a worker dead after this many ms without a heartbeat
    /// stamp (`0` = heartbeat supervision off, the default). Worker
    /// panics are detected eagerly via `WorkerFailed` regardless; this
    /// timeout additionally catches *stalls* (live thread, no
    /// progress).
    pub heartbeat_timeout_ms: u64,
    /// Take an automatic quiesced checkpoint every this many ms (`0` =
    /// off, the default). Automatic recovery restores from the latest
    /// one; without any, it restores from scratch via the full replay
    /// log.
    pub checkpoint_interval_ms: u64,
    /// How many automatic recovery attempts before the coordinator
    /// gives up and aborts with
    /// [`crate::engine::ExecError::RecoveryExhausted`].
    pub recovery_max_retries: u32,
    /// Base delay before a recovery attempt; doubles per consecutive
    /// attempt (exponential backoff).
    pub recovery_backoff_ms: u64,
    /// Deterministic fault-injection plan (empty = no faults). See
    /// [`crate::engine::FaultPlan`].
    pub fault_plan: crate::engine::FaultPlan,
    /// Use the columnar (struct-of-arrays) data plane: sources and the
    /// exchange build [`crate::column::ColumnSet`]-backed batches and
    /// operators take their column-at-a-time paths. `false` pins every
    /// batch to the row layout — the retained per-tuple path the
    /// equivalence property tests compare against; results are
    /// identical either way.
    pub columnar: bool,
    /// Out-of-core memory budget in bytes, shared by every operator of
    /// one execution (`0` = unbounded, the default — nothing ever
    /// spills). Past the budget the stateful operators (hash join,
    /// group-by, sort) and [`crate::maestro::materialize::MatStore`]
    /// spill partitions/runs/chunks to the execution's temp directory
    /// in the columnar frame format of [`crate::engine::spill`];
    /// results are byte-identical either way (the out-of-core
    /// equivalence suite pins this).
    pub memory_budget_bytes: u64,
    /// Base directory for spill files (empty = the system temp dir).
    /// Each execution creates one subdirectory lazily on first spill
    /// and removes it recursively at teardown — including cancel,
    /// abort and panic paths.
    pub spill_dir: String,

    // ---- Reshape (Ch. 3) ----
    /// Absolute-load threshold η of skew test inequality (3.1).
    pub reshape_eta: f64,
    /// Load-gap threshold τ of skew test inequality (3.2). ("we set both
    /// τ and η to 100", §3.7.1.)
    pub reshape_tau: f64,
    /// Dynamically adjust τ per Algorithm 1 (§3.4.3.2).
    pub reshape_dynamic_tau: bool,
    /// Acceptable standard-error range [ε_l, ε_u] for the estimator
    /// (§3.4.3.2; the evaluation used 98..110 tuples).
    pub reshape_eps_range: (f64, f64),
    /// Increment applied when raising τ ("increased by a fixed value of
    /// 50", §3.7.6).
    pub reshape_tau_step: f64,
    /// Max τ adjustments per execution (3 in §3.7.6).
    pub reshape_max_tau_adjust: u32,
    /// Metric-collection period in ms.
    pub reshape_metric_period_ms: u64,
    /// Initial delay before Reshape starts gathering metrics, ms
    /// ("an initial delay of 2 seconds", §3.7.1).
    pub reshape_initial_delay_ms: u64,
    /// Helpers allotted per skewed worker (1 unless the Fig. 3.26
    /// multi-helper experiment says otherwise).
    pub reshape_max_helpers: usize,
    /// Which workload metric to read.
    pub reshape_metric: WorkloadMetric,
    /// BusyTime threshold fraction classifying a worker as skewed when
    /// `reshape_metric == BusyTime` (0.8 in §3.7.12).
    pub reshape_busy_threshold: f64,
    /// Sample window (number of metric observations) for the mean-model
    /// estimator.
    pub reshape_sample_window: usize,

    // ---- elastic scaling (engine::scale) ----
    /// Autoscale: a worker queue at/above this marks the operator
    /// overloaded (scale-up signal, in tuples).
    pub autoscale_high_queue: f64,
    /// Autoscale: total queued tuples at/below this marks the operator
    /// idle (scale-down signal).
    pub autoscale_low_queue: f64,
    /// Autoscale: consecutive ticks a signal must persist before the
    /// plugin requests a scale (also sizes the post-scale cooldown).
    pub autoscale_sustain_ticks: u32,

    // ---- Maestro (Ch. 4) ----
    /// Cost-model constant: per-tuple processing cost (relative units).
    pub maestro_tuple_cost: f64,
    /// Cost-model constant: per-byte materialization write+read cost.
    pub maestro_mat_byte_cost: f64,
    /// Cost-model constant: per-byte spill write + read-back cost
    /// applied to state and materialization volume past
    /// [`Config::memory_budget_bytes`]. Starts as a rough
    /// disk-vs-memory multiple of `maestro_mat_byte_cost`; the
    /// scheduler re-calibrates it from observed [`crate::metrics::SpillStats`]
    /// bandwidth between region activations.
    pub maestro_spill_byte_cost: f64,
    /// Per-region worker budget for **elastic region scheduling**: the
    /// scheduler assigns each region's operators worker counts summing
    /// to at most this many workers, and re-plans the counts from
    /// observed statistics between region activations. The cap is **per
    /// region**, not global: Maestro's schedule is region-sequential
    /// along every dependency chain, but independent sibling regions
    /// (disjoint ancestor sets) can run concurrently and then each hold
    /// up to this many busy workers at once. `0` disables elasticity —
    /// every operator deploys at its authored `OpSpec.workers`, exactly
    /// the pre-elastic behavior.
    ///
    /// The multi-tenant serving layer (`crate::service`) reuses this
    /// same knob as its **global** budget: `EngineService` reads the
    /// service config's `max_workers` into its worker ledger and
    /// arbitrates it across *all* tenants' workflows at once (zeroing
    /// the per-job engine config's copy so a job never re-applies the
    /// cap region-locally on top of its arbitrated grant).
    pub max_workers: usize,

    // ---- misc ----
    /// RNG seed for workload generation.
    pub seed: u64,
    /// Directory holding AOT artifacts (`*.hlo.txt`).
    pub artifacts_dir: String,
}

impl Default for Config {
    fn default() -> Config {
        Config {
            batch_size: 400,
            data_queue_cap: 64,
            ctrl_check_interval: 1,
            breakpoint_tau_ms: 5,
            ctrl_delay_ms: 0,
            ft_log: false,
            heartbeat_timeout_ms: 0,
            checkpoint_interval_ms: 0,
            recovery_max_retries: 3,
            recovery_backoff_ms: 20,
            fault_plan: crate::engine::FaultPlan::default(),
            columnar: true,
            memory_budget_bytes: 0,
            spill_dir: String::new(),
            reshape_eta: 100.0,
            reshape_tau: 100.0,
            reshape_dynamic_tau: false,
            reshape_eps_range: (98.0, 110.0),
            reshape_tau_step: 50.0,
            reshape_max_tau_adjust: 3,
            reshape_metric_period_ms: 20,
            reshape_initial_delay_ms: 0,
            reshape_max_helpers: 1,
            reshape_metric: WorkloadMetric::QueueSize,
            reshape_busy_threshold: 0.8,
            reshape_sample_window: 64,
            autoscale_high_queue: 512.0,
            autoscale_low_queue: 4.0,
            autoscale_sustain_ticks: 5,
            maestro_tuple_cost: 1.0,
            maestro_mat_byte_cost: 0.01,
            maestro_spill_byte_cost: 0.05,
            max_workers: 0,
            seed: 0xA3BE12,
            artifacts_dir: "artifacts".to_string(),
        }
    }
}

impl Config {
    /// Config used by most tests: tiny batches and fast metric polling so
    /// integration tests finish in milliseconds.
    pub fn for_tests() -> Config {
        Config {
            batch_size: 16,
            data_queue_cap: 16,
            reshape_metric_period_ms: 2,
            breakpoint_tau_ms: 2,
            ..Config::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let c = Config::default();
        assert_eq!(c.batch_size, 400);
        assert_eq!(c.reshape_eta, 100.0);
        assert_eq!(c.reshape_tau, 100.0);
        assert_eq!(c.reshape_eps_range, (98.0, 110.0));
        assert_eq!(c.reshape_tau_step, 50.0);
        assert_eq!(c.ctrl_check_interval, 1);
    }

    #[test]
    fn test_config_small() {
        let c = Config::for_tests();
        assert!(c.batch_size < 100);
    }

    #[test]
    fn supervision_defaults_off() {
        // Supervision/injection must be strictly opt-in: with the
        // defaults, no heartbeat sweeps, no periodic checkpoints, no
        // faults — existing behavior is unchanged.
        let c = Config::default();
        assert_eq!(c.heartbeat_timeout_ms, 0);
        assert_eq!(c.checkpoint_interval_ms, 0);
        assert!(c.fault_plan.is_empty());
        assert!(c.recovery_max_retries > 0);
        // Out-of-core is opt-in too: unbounded budget by default, so
        // no operator ever spills and no temp directory is created.
        assert_eq!(c.memory_budget_bytes, 0);
        assert!(c.spill_dir.is_empty());
    }
}

//! Chapter 4 (Maestro) experiment harness — Table 4.1 and Figs.
//! 4.21–4.24.
//!
//! ```text
//! cargo bench --bench bench_ch4            # all experiments
//! cargo bench --bench bench_ch4 -- fig4_21 # one experiment
//! ```

use texera_amber::config::Config;
use texera_amber::engine::{OpSpec, PartitionScheme, Workflow};
use texera_amber::maestro::corpus;
use texera_amber::maestro::cost::CostParams;
use texera_amber::maestro::{enumerate_choices, MaestroScheduler};
use texera_amber::operators::basic::{Cmp, Filter};
use texera_amber::operators::{CollectSink, HashJoin, MapUdf, SinkHandle};
use texera_amber::tuple::{Tuple, Value};
use texera_amber::workloads::VecSource;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let filter = args
        .iter()
        .skip(1)
        .find(|a| a.starts_with("fig") || a.starts_with("tab"))
        .cloned();
    let run = |name: &str| filter.as_deref().map(|f| name.starts_with(f)).unwrap_or(true);

    println!("=== bench_ch4: Maestro (§4.6) ===\n");
    if run("tab4_1") {
        tab4_1_corpus();
    }
    if run("fig4_21") {
        fig4_21_22_first_response();
    }
    if run("fig4_23") {
        fig4_23_24_mat_size();
    }
}

/// Table 4.1: workflow corpus analysis.
fn tab4_1_corpus() {
    println!("--- Table 4.1: workflows from four GUI systems ---");
    println!(
        "{:<12} {:<22} {:>4} {:>6} {:>6} {:>8} {:>7} {:>8}",
        "system", "workflow", "ops", "multi", "block", "regions", "cyclic", "choices"
    );
    for r in corpus::analyze() {
        println!(
            "{:<12} {:<22} {:>4} {:>6} {:>6} {:>8} {:>7} {:>8}",
            r.system,
            r.name,
            r.operators,
            r.multi_input_ops,
            r.blocking_links,
            r.regions,
            r.cyclic,
            r.materialization_choices
        );
    }
    println!("(paper: every surveyed system has workflows needing materialization)\n");
}

/// Experiment workflow W1 (Fig. 4.20-style): self-join with an
/// expensive ML-ish operator on the probe path. Returns (workflow,
/// sink handle, sink op, scan op).
fn exp_w1(rows: usize) -> (Workflow, SinkHandle, usize, usize) {
    let mut w = Workflow::new();
    let scan = w.add(OpSpec::source("scan", 2, move |idx, parts| {
        let data: Vec<Tuple> = (0..rows)
            .filter(|i| i % parts == idx)
            .map(|i| Tuple::new(vec![Value::Int((i % 200) as i64), Value::Int(i as i64)]))
            .collect();
        Box::new(VecSource::new(data))
    }));
    // Probe path: an expensive per-tuple op (ML stand-in, 20 µs).
    let ml = w.add(OpSpec::unary("ml", 2, PartitionScheme::RoundRobin, |_, _| {
        Box::new(MapUdf::identity(20_000))
    }));
    // Build path: highly selective filter (one row per key).
    let bf = w.add(OpSpec::unary("filter_build", 2, PartitionScheme::RoundRobin, |_, _| {
        Box::new(Filter::new(1, Cmp::Lt, Value::Int(200)))
    }));
    let join = w.add(OpSpec::binary(
        "join",
        2,
        [PartitionScheme::Hash { key: 0 }, PartitionScheme::Hash { key: 0 }],
        vec![0],
        |_, _| Box::new(HashJoin::new(0, 0).strict()),
    ));
    let handle = SinkHandle::new(0);
    let h = handle.clone();
    let sink = w.add(OpSpec::unary("sink", 1, PartitionScheme::RoundRobin, move |_, _| {
        Box::new(CollectSink::new(h.clone()))
    }));
    w.connect(scan, ml, 0);
    w.connect(scan, bf, 0);
    w.connect(bf, join, 0);
    w.connect(ml, join, 1);
    w.connect(join, sink, 0);
    (w, handle, sink, scan)
}

/// Experiment workflow W2: two chained self-joins (the Fig. 4.11 shape).
fn exp_w2(rows: usize) -> (Workflow, SinkHandle, usize, usize) {
    let mut w = Workflow::new();
    let scan = w.add(OpSpec::source("scan", 2, move |idx, parts| {
        let data: Vec<Tuple> = (0..rows)
            .filter(|i| i % parts == idx)
            .map(|i| Tuple::new(vec![Value::Int((i % 100) as i64), Value::Int(i as i64)]))
            .collect();
        Box::new(VecSource::new(data))
    }));
    let f1 = w.add(OpSpec::unary("prep", 2, PartitionScheme::RoundRobin, |_, _| {
        Box::new(MapUdf::identity(5_000))
    }));
    let bf1 = w.add(OpSpec::unary("build1", 2, PartitionScheme::RoundRobin, |_, _| {
        Box::new(Filter::new(1, Cmp::Lt, Value::Int(100)))
    }));
    let j1 = w.add(OpSpec::binary(
        "join1",
        2,
        [PartitionScheme::Hash { key: 0 }, PartitionScheme::Hash { key: 0 }],
        vec![0],
        |_, _| Box::new(HashJoin::new(0, 0).strict()),
    ));
    let bf2 = w.add(OpSpec::unary("build2", 2, PartitionScheme::RoundRobin, |_, _| {
        Box::new(Filter::new(1, Cmp::Lt, Value::Int(100)))
    }));
    let j2 = w.add(OpSpec::binary(
        "join2",
        2,
        [PartitionScheme::Hash { key: 0 }, PartitionScheme::Hash { key: 0 }],
        vec![0],
        |_, _| Box::new(HashJoin::new(0, 0).strict()),
    ));
    let handle = SinkHandle::new(0);
    let h = handle.clone();
    let sink = w.add(OpSpec::unary("sink", 1, PartitionScheme::RoundRobin, move |_, _| {
        Box::new(CollectSink::new(h.clone()))
    }));
    w.connect(scan, f1, 0);
    w.connect(scan, bf1, 0);
    w.connect(bf1, j1, 0);
    w.connect(f1, j1, 1);
    w.connect(scan, bf2, 0);
    w.connect(bf2, j2, 0);
    w.connect(j1, j2, 1);
    w.connect(j2, sink, 0);
    (w, handle, sink, scan)
}

/// Figs. 4.21/4.22: measured first response time per materialization
/// choice across input sizes.
fn fig4_21_22_first_response() {
    for (wf_name, builder) in [
        ("W1", exp_w1 as fn(usize) -> (Workflow, SinkHandle, usize, usize)),
        ("W2", exp_w2),
    ] {
        println!("--- Figs 4.21/4.22: first response time ({wf_name}) ---");
        println!("{:>8} {:>8} {:>18} {:>12} {:>12}", "rows", "choice", "edges", "est FRT", "FRT (s)");
        for rows in [10_000usize, 20_000, 40_000] {
            let (w0, _, sink, scan) = builder(rows);
            let mut cost = CostParams::new();
            cost.source_rows.insert(scan, rows as f64);
            let choices = enumerate_choices(&w0, 2);
            for (ci, c) in choices.iter().enumerate() {
                let (w, _handle, sink2, _) = builder(rows);
                assert_eq!(sink, sink2);
                let (est, _) = texera_amber::maestro::first_response_time(&w0, c, &cost, &[sink]);
                let sched = MaestroScheduler::new(Config::default(), cost.clone());
                let outcome = sched.run_with_choice(w, &[sink], c, est);
                let names: Vec<String> = c
                    .iter()
                    .map(|&ei| {
                        let e = w0.edges[ei];
                        format!("{}→{}", w0.ops[e.from].name, w0.ops[e.to].name)
                    })
                    .collect();
                println!(
                    "{rows:>8} {ci:>8} {:>18} {est:>12.0} {:>12.3}",
                    names.join(","),
                    outcome.measured_frt
                );
            }
        }
        println!("(paper: the choice gap widens with input size; the planner's pick stays lowest)\n");
    }
}

/// Figs. 4.23/4.24: materialized bytes per choice across input sizes.
fn fig4_23_24_mat_size() {
    for (wf_name, builder) in [
        ("W1", exp_w1 as fn(usize) -> (Workflow, SinkHandle, usize, usize)),
        ("W2", exp_w2),
    ] {
        println!("--- Figs 4.23/4.24: materialization size ({wf_name}) ---");
        println!("{:>8} {:>8} {:>18} {:>14}", "rows", "choice", "edges", "bytes");
        for rows in [10_000usize, 20_000, 40_000] {
            let (w0, _, sink, _) = builder(rows);
            let choices = enumerate_choices(&w0, 2);
            for (ci, c) in choices.iter().enumerate() {
                let (w, _handle, sink2, _) = builder(rows);
                assert_eq!(sink, sink2);
                let sched = MaestroScheduler::new(Config::default(), CostParams::new());
                let outcome = sched.run_with_choice(w, &[sink], c, 0.0);
                let names: Vec<String> = c
                    .iter()
                    .map(|&ei| {
                        let e = w0.edges[ei];
                        format!("{}→{}", w0.ops[e.from].name, w0.ops[e.to].name)
                    })
                    .collect();
                println!(
                    "{rows:>8} {ci:>8} {:>18} {:>14}",
                    names.join(","),
                    outcome.mat_bytes.iter().sum::<u64>()
                );
            }
        }
        println!("(paper: materialized volume scales linearly; choices differ by what they defer)\n");
    }
}

//! Chapter 2 (Amber) experiment harness — regenerates every table and
//! figure of §2.7 at single-machine scale.
//!
//! ```text
//! cargo bench --bench bench_ch2              # all experiments
//! cargo bench --bench bench_ch2 -- fig2_10   # one experiment
//! ```
//!
//! Scale substitution (DESIGN.md §3): the paper's machines become
//! worker threads; data sizes shrink from TB to MB. Shapes — flat
//! per-worker scaleup throughput, sub-second pause latency, τ's effect
//! on breakpoint overhead, Amber-vs-Spark parity, the checkpoint
//! file-count penalty — are the reproduction targets, not absolute
//! numbers.

use std::time::{Duration, Instant};

use texera_amber::batch::{run_batch, BatchConfig, FileLayout};
use texera_amber::config::Config;
use texera_amber::engine::{Execution, OpSpec, PartitionScheme, WorkerId, Workflow};
use texera_amber::flows;
use texera_amber::metrics::Summary;
use texera_amber::operators::{CollectSink, MapUdf, SinkHandle};
use texera_amber::tuple::{Tuple, Value};
use texera_amber::workloads::tweets::TweetSource;
use texera_amber::workloads::{TupleSource, VecSource};

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let filter = args
        .iter()
        .skip(1)
        .find(|a| a.starts_with("fig") || a.starts_with("sec"))
        .cloned();
    let run = |name: &str| filter.as_deref().map(|f| name.starts_with(f)).unwrap_or(true);

    println!("=== bench_ch2: Amber (§2.7) ===\n");
    if run("fig2_8") {
        fig2_8_scaleup();
    }
    if run("fig2_9") {
        fig2_9_speedup();
    }
    if run("fig2_10") {
        fig2_10_11_pause_time();
    }
    if run("fig2_12") {
        fig2_12_worker_count();
    }
    if run("fig2_13") {
        fig2_13_breakpoint_tau();
    }
    if run("fig2_14") {
        fig2_14_15_vs_batch();
    }
    if run("fig2_16") {
        fig2_16_checkpoint_overhead();
    }
    if run("sec2_7_8") {
        sec2_7_8_recovery();
    }
}

/// Fig. 2.8: scaleup — data size and worker count grow together; the
/// paper's curve is near-flat. On one physical core wall time grows
/// with data, so the reproduced invariant is per-worker throughput.
fn fig2_8_scaleup() {
    println!("--- Fig 2.8: scaleup (W1=Q1-style, W2=Q13-style) ---");
    println!("{:>8} {:>8} {:>10} {:>10} {:>16}", "workers", "sf", "W1 (s)", "W2 (s)", "ktup/s/wkr");
    for (workers, sf) in [(1usize, 2.5f64), (2, 5.0), (4, 10.0), (8, 20.0)] {
        let f1 = flows::tpch_q1(sf, workers);
        let t0 = Instant::now();
        Execution::start(f1.workflow, Config::default()).join();
        let w1 = t0.elapsed();
        let f2 = flows::tpch_q13(sf, workers);
        let t0 = Instant::now();
        Execution::start(f2.workflow, Config::default()).join();
        let w2 = t0.elapsed();
        let rows = sf * 60_000.0;
        println!(
            "{:>8} {:>8.2} {:>10.2} {:>10.2} {:>16.0}",
            workers,
            sf,
            w1.as_secs_f64(),
            w2.as_secs_f64(),
            rows / w1.as_secs_f64() / workers as f64 / 1_000.0
        );
    }
    println!();
}

/// Fig. 2.9: speedup — fixed data, workers 1→8. (Thread-level speedup
/// is bounded by the single core; the engine-overhead curve is the
/// observable.)
fn fig2_9_speedup() {
    println!("--- Fig 2.9: speedup (fixed sf=10) ---");
    println!("{:>8} {:>10} {:>10} {:>9}", "workers", "W1 (s)", "W2 (s)", "W1 ratio");
    let mut base = None;
    for workers in [1usize, 2, 4, 8] {
        let f1 = flows::tpch_q1(10.0, workers);
        let t0 = Instant::now();
        Execution::start(f1.workflow, Config::default()).join();
        let w1 = t0.elapsed().as_secs_f64();
        let f2 = flows::tpch_q13(10.0, workers);
        let t0 = Instant::now();
        Execution::start(f2.workflow, Config::default()).join();
        let w2 = t0.elapsed().as_secs_f64();
        let b = *base.get_or_insert(w1);
        println!("{workers:>8} {w1:>10.2} {w2:>10.2} {:>9.2}", b / w1);
    }
    println!();
}

/// Figs. 2.10/2.11: pause latency percentiles while scaling up — the
/// paper's claim is "all times < 1 second".
fn fig2_10_11_pause_time() {
    println!("--- Figs 2.10/2.11: time to pause (candlesticks, ms) ---");
    println!(
        "{:>4} {:>8} {:>8} {:>8} {:>8} {:>8} {:>8}",
        "wf", "workers", "p1", "p25", "p50", "p75", "p99"
    );
    for (name, which) in [("W1", 1), ("W2", 2)] {
        for workers in [2usize, 4, 8] {
            let f = if which == 1 {
                flows::tpch_q1(10.0, workers)
            } else {
                flows::tpch_q13(10.0, workers)
            };
            let exec = Execution::start(f.workflow, Config::default());
            let mut s = Summary::new();
            // "Each execution was interrupted 8 times."
            for _ in 0..8 {
                std::thread::sleep(Duration::from_millis(15));
                let lat = exec.pause();
                s.record(lat.as_secs_f64() * 1e3);
                exec.resume();
            }
            exec.join();
            let c = s.candlestick();
            println!(
                "{name:>4} {workers:>8} {:>8.2} {:>8.2} {:>8.2} {:>8.2} {:>8.2}",
                c[0], c[1], c[2], c[3], c[4]
            );
        }
    }
    println!("(paper: all sub-second; expect sub-10ms at this scale)\n");
}

/// Fig. 2.12: worker count for an expensive ML-style operator (W3) —
/// time falls as workers grow, then rises past the useful parallelism.
fn fig2_12_worker_count() {
    println!("--- Fig 2.12: SentimentAnalysis worker count (W3) ---");
    println!("{:>8} {:>10}", "workers", "time (s)");
    // 600 tweets through a 5 ms/tuple latency-bound UDF (the paper:
    // 1,578 tweets at ~4 s/tuple).
    let tuples = 600usize;
    for workers in [1usize, 2, 5, 10, 20, 50, 100] {
        let mut w = Workflow::new();
        let scan = w.add(OpSpec::source("scan", 2, move |idx, parts| {
            Box::new(TweetSource::new(tuples, parts, idx, 5)) as Box<dyn TupleSource>
        }));
        let ml = w.add(OpSpec::unary("sentiment", workers, PartitionScheme::RoundRobin, |_, _| {
            Box::new(MapUdf::identity(5_000_000)) // 5 ms per tuple
        }));
        let handle = SinkHandle::new(0);
        let h = handle.clone();
        let sink = w.add(OpSpec::unary("sink", 1, PartitionScheme::RoundRobin, move |_, _| {
            Box::new(CollectSink::new(h.clone()))
        }));
        w.connect(scan, ml, 0);
        w.connect(ml, sink, 0);
        // Small batches so tuples spread across ML workers (the paper
        // used batch size 25 here for the same reason).
        let cfg = Config { batch_size: 5, ..Config::default() };
        let t0 = Instant::now();
        Execution::start(w, cfg).join();
        println!("{workers:>8} {:>10.2}", t0.elapsed().as_secs_f64());
    }
    println!("(paper: U-shape — falls to ~40 workers, rises past capacity)\n");
}

/// Fig. 2.13: conditional-breakpoint running time vs the principal's
/// waiting threshold τ, plus the no-breakpoint baseline.
fn fig2_13_breakpoint_tau() {
    println!("--- Fig 2.13: breakpoint τ sweep ---");
    println!("{:>10} {:>12}", "tau (ms)", "time (s)");
    let total = 400_000usize;
    let target = 300_000u64;
    let mk = || {
        let mut w = Workflow::new();
        let scan = w.add(OpSpec::source("scan", 2, move |idx, parts| {
            let rows: Vec<Tuple> = (0..total)
                .skip(idx)
                .step_by(parts)
                .map(|i| Tuple::new(vec![Value::Int(i as i64)]))
                .collect();
            Box::new(VecSource::new(rows)) as Box<dyn TupleSource>
        }));
        let filter = w.add(OpSpec::unary("filter", 3, PartitionScheme::RoundRobin, |_, _| {
            Box::new(texera_amber::operators::basic::Filter::new(
                0,
                texera_amber::operators::basic::Cmp::Ge,
                Value::Int(0),
            ))
        }));
        let handle = SinkHandle::new(0);
        let h = handle.clone();
        let sink = w.add(OpSpec::unary("sink", 1, PartitionScheme::RoundRobin, move |_, _| {
            Box::new(CollectSink::new(h.clone()))
        }));
        w.connect(scan, filter, 0);
        w.connect(filter, sink, 0);
        (w, scan, filter)
    };
    for tau_ms in [0u64, 1, 5, 20, 100] {
        let (w, scan, filter) = mk();
        let cfg = Config { breakpoint_tau_ms: tau_ms, ..Config::default() };
        let exec = Execution::start_scheduled(w, cfg);
        exec.set_count_breakpoint(filter, target);
        let t0 = Instant::now();
        exec.start_sources(vec![scan]);
        exec.await_breakpoint();
        let t = t0.elapsed();
        println!("{tau_ms:>10} {:>12.2}", t.as_secs_f64());
        exec.resume();
        exec.join();
    }
    // Baseline: no breakpoint, same production volume.
    let (w, _, _) = mk();
    let t0 = Instant::now();
    Execution::start(w, Config::default()).join();
    println!("{:>10} {:>12.2} (no breakpoint, full run)", "-", t0.elapsed().as_secs_f64());
    println!("(paper: lower τ → less sync time; breakpoint overhead small)\n");
}

/// Figs. 2.14/2.15: pipelined engine vs the stage-by-stage batch
/// comparator (the Spark stand-in) on W1 and W2.
fn fig2_14_15_vs_batch() {
    println!("--- Figs 2.14/2.15: Amber vs batch engine ---");
    println!(
        "{:>4} {:>8} {:>8} {:>12} {:>12}",
        "wf", "workers", "sf", "amber (s)", "batch (s)"
    );
    for (name, which) in [("W1", 1), ("W2", 2)] {
        for (workers, sf) in [(2usize, 2.5f64), (4, 5.0), (8, 10.0)] {
            let f = if which == 1 {
                flows::tpch_q1(sf, workers)
            } else {
                flows::tpch_q13(sf, workers)
            };
            let wf_batch = f.workflow.clone();
            let t0 = Instant::now();
            Execution::start(f.workflow, Config::default()).join();
            let amber = t0.elapsed().as_secs_f64();
            let t0 = Instant::now();
            run_batch(&wf_batch, &BatchConfig::default());
            let batch = t0.elapsed().as_secs_f64();
            println!("{name:>4} {workers:>8} {sf:>8.2} {amber:>12.2} {batch:>12.2}");
        }
    }
    println!("(paper: Amber comparable to Spark on both workflows)\n");
}

/// Fig. 2.16: checkpointing overhead — per-partition files (Amber-like)
/// vs consolidated blocks (Spark-like) vs no checkpointing.
fn fig2_16_checkpoint_overhead() {
    println!("--- Fig 2.16: data-checkpointing overhead (W2) ---");
    println!(
        "{:>8} {:>10} {:>12} {:>12} {:>8} {:>8}",
        "workers", "none (s)", "perpart (s)", "consol (s)", "files-p", "files-c"
    );
    for (workers, sf) in [(2usize, 2.5f64), (4, 5.0), (8, 10.0)] {
        let f = flows::tpch_q13(sf, workers);
        let w = f.workflow;
        let t0 = Instant::now();
        run_batch(&w, &BatchConfig::default());
        let none = t0.elapsed().as_secs_f64();
        let dir1 = format!("/tmp/amber_ck_pp_{workers}");
        let t0 = Instant::now();
        let s1 = run_batch(
            &w,
            &BatchConfig {
                checkpoint_dir: Some(dir1.clone()),
                layout: FileLayout::PerPartition,
            },
        );
        let pp = t0.elapsed().as_secs_f64();
        let dir2 = format!("/tmp/amber_ck_cs_{workers}");
        let t0 = Instant::now();
        let s2 = run_batch(
            &w,
            &BatchConfig {
                checkpoint_dir: Some(dir2.clone()),
                layout: FileLayout::Consolidated { block_bytes: 1 << 20 },
            },
        );
        let cs = t0.elapsed().as_secs_f64();
        let _ = std::fs::remove_dir_all(dir1);
        let _ = std::fs::remove_dir_all(dir2);
        println!(
            "{workers:>8} {none:>10.2} {pp:>12.2} {cs:>12.2} {:>8} {:>8}",
            s1.files_written, s2.files_written
        );
    }
    println!("(paper: Amber's per-partition files grow quadratically and overtake Spark)\n");
}

/// §2.7.8: crash recovery — completion time with a mid-run failure
/// (checkpoint → crash → recover) vs no failure.
fn sec2_7_8_recovery() {
    println!("--- §2.7.8: crash recovery (W2-style pipeline) ---");
    let sf = 20.0f64;
    let workers = 4;
    // No-failure baseline.
    let f = flows::tpch_q13(sf, workers);
    let t0 = Instant::now();
    Execution::start(f.workflow, Config::default()).join();
    let clean = t0.elapsed().as_secs_f64();
    // With failure: checkpoint mid-run, crash a join worker, recover.
    let cfg = Config { ft_log: true, ..Config::default() };
    let f = flows::tpch_q13(sf, workers);
    let t0 = Instant::now();
    let exec = Execution::start(f.workflow, cfg.clone());
    std::thread::sleep(Duration::from_millis(100));
    let cp = exec.checkpoint();
    std::thread::sleep(Duration::from_millis(50));
    exec.crash_workers(vec![WorkerId::new(f.focus, 0)]);
    let log = exec.take_replay_log();
    drop(exec);
    let f2 = flows::tpch_q13(sf, workers);
    Execution::recover(f2.workflow, cfg, cp, log).join();
    let with_failure = t0.elapsed().as_secs_f64();
    println!(
        "no failure: {clean:.2}s | crash+recover: {with_failure:.2}s ({:.0}% overhead)",
        (with_failure / clean - 1.0) * 100.0
    );
    println!("(paper: 176s with crash vs 153s clean ≈ 15% overhead)\n");
}

//! Chapter 3 (Reshape) experiment harness — regenerates the figures and
//! tables of §3.7 at single-machine scale.
//!
//! ```text
//! cargo bench --bench bench_ch3              # all experiments
//! cargo bench --bench bench_ch3 -- fig3_20   # one experiment
//! ```
//!
//! The join operators carry an artificial per-probe cost so they are
//! the bottleneck (the §3.3.1 premise); queue capacities are sized so
//! backlogs form on skewed workers. Reproduction targets are the
//! *relative* behaviours: who balances load, who can split a heavy
//! hitter, how fast the observed result ratio converges.

use std::time::{Duration, Instant};

use texera_amber::config::{Config, WorkloadMetric};
use texera_amber::engine::controller::CoordPlugin;
use texera_amber::engine::{ExecSummary, Execution};
use texera_amber::flows::{
    dsb_q18_costed, synthetic_join_costed, tweet_join_costed, worker_of_key,
};
use texera_amber::metrics::Summary;
use texera_amber::operators::SinkHandle;
use texera_amber::reshape::baselines::{FlowJoinPlugin, FluxPlugin};
use texera_amber::reshape::{Approach, ReshapePlugin};
use texera_amber::workloads::tweets;

const PROBE_COST: u64 = 12_000; // ns per probe tuple → join is bottleneck

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let filter = args
        .iter()
        .skip(1)
        .find(|a| a.starts_with("fig") || a.starts_with("tab"))
        .cloned();
    let run = |name: &str| filter.as_deref().map(|f| name.starts_with(f)).unwrap_or(true);

    println!("=== bench_ch3: Reshape (§3.7) ===\n");
    if run("fig3_16") {
        fig3_16_17_result_ratio();
    }
    if run("fig3_18") {
        fig3_18_19_first_phase();
    }
    if run("fig3_20") {
        fig3_20_heavy_hitters();
    }
    if run("fig3_21") {
        fig3_21_control_latency();
    }
    if run("fig3_22") {
        fig3_22_dynamic_tau();
    }
    if run("fig3_23") {
        fig3_23_skew_levels();
    }
    if run("fig3_24") {
        fig3_24_distribution_change();
    }
    if run("fig3_25") {
        fig3_25_metric_overhead();
    }
    if run("tab3_2") {
        tab3_2_sort();
    }
    if run("fig3_26") {
        fig3_26_multi_helpers();
    }
    if run("fig3_27") {
        fig3_27_alt_metric();
    }
}

#[derive(Clone, Copy, Debug, PartialEq)]
enum Strategy {
    None,
    Flux,
    FlowJoin { delay_ms: u64 },
    Reshape,
    ReshapeNoPhase1,
}

impl Strategy {
    fn name(&self) -> String {
        match self {
            Strategy::None => "unmitigated".into(),
            Strategy::Flux => "flux".into(),
            Strategy::FlowJoin { delay_ms } => format!("flow-join({delay_ms}ms)"),
            Strategy::Reshape => "reshape".into(),
            Strategy::ReshapeNoPhase1 => "reshape-no-p1".into(),
        }
    }

    /// Build the plugin and a handle to its chosen (skewed, helper)
    /// pairs, so harnesses measure skewed-vs-helper load balance the
    /// way the paper does (§3.7.4).
    fn plugin(
        &self,
        join: usize,
    ) -> (Option<Box<dyn CoordPlugin>>, PairsHandle) {
        match self {
            Strategy::None => (None, PairsHandle::None),
            Strategy::Flux => {
                let p = FluxPlugin::new(join);
                let h = PairsHandle::Raw(p.pairs());
                (Some(Box::new(p)), h)
            }
            Strategy::FlowJoin { delay_ms } => {
                let p = FlowJoinPlugin::new(join, *delay_ms);
                let h = PairsHandle::Raw(p.pairs());
                (Some(Box::new(p)), h)
            }
            Strategy::Reshape => {
                let p = ReshapePlugin::new(join, Approach::SplitByRecords, true);
                let h = PairsHandle::Report(p.report());
                (Some(Box::new(p)), h)
            }
            Strategy::ReshapeNoPhase1 => {
                let p = ReshapePlugin::new(join, Approach::SplitByRecords, true)
                    .without_phase1();
                let h = PairsHandle::Report(p.report());
                (Some(Box::new(p)), h)
            }
        }
    }
}

/// Access to the (skewed, helper) pairs a strategy chose.
enum PairsHandle {
    None,
    Raw(std::sync::Arc<std::sync::Mutex<Vec<(usize, usize)>>>),
    Report(std::sync::Arc<std::sync::Mutex<texera_amber::reshape::ReshapeReport>>),
}

impl PairsHandle {
    fn pairs(&self) -> Vec<(usize, usize)> {
        match self {
            PairsHandle::None => Vec::new(),
            PairsHandle::Raw(p) => p.lock().unwrap().clone(),
            PairsHandle::Report(r) => r
                .lock()
                .unwrap()
                .mitigations
                .iter()
                .map(|(_, s, h)| (*s, h[0]))
                .collect(),
        }
    }

    fn iterations(&self) -> u32 {
        match self {
            PairsHandle::Report(r) => r.lock().unwrap().iterations,
            _ => 0,
        }
    }
}

/// Queue-heavy config: skewed workers build real backlogs (the paper's
/// "input at an equal or higher rate than they can process").
fn skew_cfg() -> Config {
    // Small bounded queues: the skewed worker's queue saturates and
    // backpressure keeps "future input" at the sources, giving the
    // mitigation something to redirect. (The paper's testbed has
    // effectively unbounded queues over a 400 s run; with bounded
    // queues the unmitigated ratio distortion is milder but the
    // mitigation dynamics are preserved — see EXPERIMENTS.md.)
    Config {
        batch_size: 64,
        data_queue_cap: 16,
        reshape_eta: 100.0,
        reshape_tau: 100.0,
        reshape_initial_delay_ms: 50,
        ..Config::default()
    }
}

/// Sample `sink.ratio(CA, AZ)` while the execution runs; returns the
/// (seconds, ratio) timeline.
fn sample_ratio(
    exec: &Execution,
    sink: &SinkHandle,
    total: usize,
    sample_ms: u64,
) -> Vec<(f64, f64)> {
    let t0 = Instant::now();
    let mut timeline = Vec::new();
    loop {
        std::thread::sleep(Duration::from_millis(sample_ms));
        let r = sink.ratio(tweets::CA, tweets::AZ);
        if r.is_finite() {
            timeline.push((t0.elapsed().as_secs_f64(), r));
        }
        if sink.total() as usize >= total || t0.elapsed() > Duration::from_secs(60) {
            break;
        }
    }
    let _ = exec;
    timeline
}

/// Load-balance ratio (§3.7.4) between the CA worker and its *helper*
/// — the worker the strategy chose; the least-loaded other worker when
/// no pair was chosen (the strategy effectively left CA alone).
fn ca_lbr(summary: &ExecSummary, join: usize, workers: usize, pairs: &PairsHandle) -> f64 {
    let ca_worker = worker_of_key(tweets::CA as i64, workers);
    let get = |idx: usize| {
        summary
            .worker_stats
            .iter()
            .find(|(id, _)| id.op == join && id.idx == idx)
            .map(|(_, s)| s.processed as f64)
            .unwrap_or(0.0)
    };
    let helper = pairs
        .pairs()
        .iter()
        .find(|(s, _)| *s == ca_worker)
        .map(|(_, h)| *h)
        .unwrap_or_else(|| {
            (0..workers)
                .filter(|&i| i != ca_worker)
                .min_by(|&a, &b| get(a).partial_cmp(&get(b)).unwrap())
                .unwrap_or(0)
        });
    let (a, b) = (get(ca_worker), get(helper));
    if a.max(b) > 0.0 {
        a.min(b) / a.max(b)
    } else {
        f64::NAN
    }
}

/// Figs. 3.16/3.17: |observed − actual| CA:AZ ratio over time per
/// strategy. Reshape should converge earliest and stay converged.
fn fig3_16_17_result_ratio() {
    println!("--- Figs 3.16/3.17: result ratio CA:AZ over time ---");
    let total = 120_000;
    let actual = tweets::CA_AZ_RATIO;
    println!("actual ratio: {actual:.2}; entries are |observed − actual|");
    for strategy in [
        Strategy::None,
        Strategy::Flux,
        Strategy::FlowJoin { delay_ms: 100 },
        Strategy::Reshape,
    ] {
        let f = tweet_join_costed(total, 8, 0xC0FFEE, PROBE_COST);
        let (plugin, _pairs) = strategy.plugin(f.focus);
        let exec = match plugin {
            Some(p) => Execution::start_with_plugin(f.workflow, skew_cfg(), p),
            None => Execution::start(f.workflow, skew_cfg()),
        };
        let timeline = sample_ratio(&exec, &f.sink, total, 100);
        exec.join();
        let step = (timeline.len() / 6).max(1);
        let pts: Vec<String> = timeline
            .iter()
            .step_by(step)
            .take(6)
            .map(|(t, r)| format!("{t:.1}s:{:.2}", (r - actual).abs()))
            .collect();
        println!("{:>18} | {}", strategy.name(), pts.join("  "));
    }
    println!("(paper: Reshape reaches and holds the actual ratio earliest)\n");
}

/// Figs. 3.18/3.19: the first (catch-up) phase lets the representative
/// ratio appear earlier.
fn fig3_18_19_first_phase() {
    println!("--- Figs 3.18/3.19: benefit of the first phase ---");
    let total = 120_000;
    let actual = tweets::CA_AZ_RATIO;
    for strategy in [Strategy::Reshape, Strategy::ReshapeNoPhase1, Strategy::None] {
        let f = tweet_join_costed(total, 8, 0xC0FFEE, PROBE_COST);
        let (plugin, _pairs) = strategy.plugin(f.focus);
        let exec = match plugin {
            Some(p) => Execution::start_with_plugin(f.workflow, skew_cfg(), p),
            None => Execution::start(f.workflow, skew_cfg()),
        };
        let timeline = sample_ratio(&exec, &f.sink, total, 80);
        let summary = exec.join();
        let mut tl = texera_amber::metrics::Timeline::new();
        for (t, r) in &timeline {
            tl.record_at(*t, *r);
        }
        let conv = tl.time_to_converge(actual, actual * 0.12);
        println!(
            "{:>18} | time to ±12% of actual: {} (run {:.2}s)",
            strategy.name(),
            conv.map(|t| format!("{t:.2}s")).unwrap_or("never".into()),
            summary.elapsed.as_secs_f64()
        );
    }
    println!("(paper: with phase 1 ≈ 120s vs without ≈ 288s, both beat unmitigated)\n");
}

/// Fig. 3.20: heavy-hitter handling per strategy and worker count.
fn fig3_20_heavy_hitters() {
    println!("--- Fig 3.20: heavy-hitter key (California) ---");
    println!("{:>8} {:>18} {:>8} {:>10}", "workers", "strategy", "LBR", "time (s)");
    let total = 100_000;
    for workers in [8usize, 12] {
        for strategy in [
            Strategy::Flux,
            Strategy::FlowJoin { delay_ms: 50 },
            Strategy::FlowJoin { delay_ms: 150 },
            Strategy::FlowJoin { delay_ms: 400 },
            Strategy::Reshape,
        ] {
            let f = tweet_join_costed(total, workers, 0xC0FFEE, PROBE_COST);
            let join = f.focus;
            let (plugin, pairs) = strategy.plugin(join);
            let exec = match plugin {
                Some(p) => Execution::start_with_plugin(f.workflow, skew_cfg(), p),
                None => Execution::start(f.workflow, skew_cfg()),
            };
            let summary = exec.join();
            println!(
                "{workers:>8} {:>18} {:>8.2} {:>10.2}",
                strategy.name(),
                ca_lbr(&summary, join, workers, &pairs),
                summary.elapsed.as_secs_f64()
            );
        }
    }
    println!("(paper: Reshape ≈0.92; Flow-Join 0.6–0.85 falling with delay; Flux ≈0.06)\n");
}

/// Fig. 3.21: artificial control-message delivery delay degrades load
/// balance.
fn fig3_21_control_latency() {
    println!("--- Fig 3.21: control-message latency ---");
    println!("{:>12} {:>8} {:>10}", "delay (ms)", "LBR", "time (s)");
    let total = 100_000;
    for delay in [0u64, 50, 150, 400] {
        let cfg = Config { ctrl_delay_ms: delay, ..skew_cfg() };
        let f = tweet_join_costed(total, 8, 0xC0FFEE, PROBE_COST);
        let join = f.focus;
        let plugin = ReshapePlugin::new(join, Approach::SplitByRecords, true);
        let pairs = PairsHandle::Report(plugin.report());
        let exec = Execution::start_with_plugin(f.workflow, cfg, Box::new(plugin));
        let summary = exec.join();
        println!(
            "{delay:>12} {:>8.2} {:>10.2}",
            ca_lbr(&summary, join, 8, &pairs),
            summary.elapsed.as_secs_f64()
        );
    }
    println!("(paper: LBR 0.94 at no delay → 0.45 at 15 s delay)\n");
}

/// Fig. 3.22: fixed vs dynamically adjusted τ — load balance per
/// mitigation iteration.
fn fig3_22_dynamic_tau() {
    println!("--- Fig 3.22: dynamic τ adjustment ---");
    println!(
        "{:>8} {:>8} {:>6} {:>8} {:>14}",
        "tau", "dynamic", "iters", "LBR", "LBR/iteration"
    );
    let total = 100_000;
    for tau in [10.0f64, 100.0, 500.0, 1500.0] {
        for dynamic in [false, true] {
            let f = tweet_join_costed(total, 8, 0xC0FFEE, PROBE_COST);
            let join = f.focus;
            let cfg = Config {
                reshape_tau: tau,
                reshape_dynamic_tau: dynamic,
                ..skew_cfg()
            };
            let plugin = ReshapePlugin::new(join, Approach::SplitByRecords, true);
            let pairs = PairsHandle::Report(plugin.report());
            let exec = Execution::start_with_plugin(f.workflow, cfg, Box::new(plugin));
            let summary = exec.join();
            let iters = pairs.iterations().max(1);
            let lbr = ca_lbr(&summary, join, 8, &pairs);
            println!(
                "{tau:>8.0} {dynamic:>8} {iters:>6} {lbr:>8.2} {:>14.3}",
                lbr / iters as f64
            );
        }
    }
    println!("(paper: dynamic τ cuts iteration counts at low τ and rescues high τ)\n");
}

/// Fig. 3.23: high (item) vs moderate (date) skew.
fn fig3_23_skew_levels() {
    println!("--- Fig 3.23: skew levels (W2 on DSB-like data) ---");
    println!(
        "{:>8} {:>8} {:>14} {:>14} {:>10}",
        "rows", "workers", "item-join LBR", "date-join LBR", "time (s)"
    );
    for (rows, workers) in [(40_000usize, 4usize), (80_000, 8)] {
        let (f, j_item, j_date) = dsb_q18_costed(rows, workers, 7, PROBE_COST / 2);
        let p_item = ReshapePlugin::new(j_item, Approach::SplitByRecords, true);
        let rep_item = p_item.report();
        let exec = Execution::start_with_plugin(f.workflow, skew_cfg(), Box::new(p_item));
        let summary = exec.join();
        let loads_of = |op: usize| -> Vec<f64> {
            (0..workers)
                .map(|i| {
                    summary
                        .worker_stats
                        .iter()
                        .find(|(id, _)| id.op == op && id.idx == i)
                        .map(|(_, s)| s.processed as f64)
                        .unwrap_or(0.0)
                })
                .collect()
        };
        // item join: mitigated pair's LBR; date join (unprotected in
        // this run): spread min/max as its balance measure.
        let item_lbr = {
            let loads = loads_of(j_item);
            let rg = rep_item.lock().unwrap();
            match rg.mitigations.first() {
                Some((_, s, h)) => {
                    let (a, b) = (loads[*s], loads[h[0]]);
                    a.min(b) / a.max(b)
                }
                None => {
                    let max = loads.iter().cloned().fold(0.0f64, f64::max);
                    let min = loads.iter().cloned().fold(f64::INFINITY, f64::min);
                    min / max
                }
            }
        };
        let date_lbr = {
            let loads = loads_of(j_date);
            let max = loads.iter().cloned().fold(0.0f64, f64::max);
            let min = loads.iter().cloned().fold(f64::INFINITY, f64::min);
            min / max
        };
        println!(
            "{rows:>8} {workers:>8} {item_lbr:>14.2} {date_lbr:>14.2} {:>10.2}",
            summary.elapsed.as_secs_f64()
        );
    }
    println!("(paper: high skew detected early → LBR > 0.77; moderate skew lower)\n");
}

/// Fig. 3.24: mid-run input-distribution change.
fn fig3_24_distribution_change() {
    println!("--- Fig 3.24: input-distribution change (W4) ---");
    let rows = 60_000;
    let workers = 6;
    let hot = worker_of_key(texera_amber::workloads::synthetic::HOT_KEY, workers);
    for strategy in [
        Strategy::Flux,
        Strategy::FlowJoin { delay_ms: 80 },
        Strategy::Reshape,
    ] {
        let f = synthetic_join_costed(rows, workers, 11, PROBE_COST / 2);
        let join = f.focus;
        let cfg = Config { reshape_tau: 500.0, ..skew_cfg() };
        let (plugin, _pairs) = strategy.plugin(join);
        let exec = match plugin {
            Some(p) => Execution::start_with_plugin(f.workflow, cfg, p),
            None => Execution::start(f.workflow, cfg),
        };
        let t0 = Instant::now();
        let mut pts = Vec::new();
        loop {
            std::thread::sleep(Duration::from_millis(200));
            let stats = exec.stats();
            let get = |idx: usize| {
                stats
                    .iter()
                    .find(|(id, _)| id.op == join && id.idx == idx)
                    .map(|(_, s)| s.processed as f64)
                    .unwrap_or(0.0)
            };
            let skewed_load = get(hot);
            let max_other = (0..workers)
                .filter(|&i| i != hot)
                .map(get)
                .fold(0.0f64, f64::max);
            if skewed_load > 0.0 {
                pts.push((t0.elapsed().as_secs_f64(), max_other / skewed_load));
            }
            if t0.elapsed() > Duration::from_secs(30) || pts.len() >= 10 {
                break;
            }
        }
        exec.join();
        let s: Vec<String> = pts
            .iter()
            .map(|(t, r)| format!("{t:.1}s:{r:.2}"))
            .collect();
        println!("{:>18} | helper/skewed load: {}", strategy.name(), s.join(" "));
    }
    println!("(paper: Reshape re-adjusts to ≈1 after the shift; Flow-Join overshoots; Flux ≈0)\n");
}

/// Fig. 3.25: metric-collection overhead.
fn fig3_25_metric_overhead() {
    println!("--- Fig 3.25: metric-collection overhead (W2) ---");
    println!("{:>8} {:>12} {:>12} {:>9}", "rows", "off (s)", "on (s)", "overhead");
    for rows in [40_000usize, 80_000] {
        let (f, _, _) = dsb_q18_costed(rows, 4, 7, PROBE_COST / 4);
        let t0 = Instant::now();
        Execution::start(f.workflow, skew_cfg()).join();
        let off = t0.elapsed().as_secs_f64();
        let (f, j_item, _) = dsb_q18_costed(rows, 4, 7, PROBE_COST / 4);
        // Metrics on but detection unreachable → pure collection cost.
        let cfg = Config { reshape_eta: f64::INFINITY, ..skew_cfg() };
        let plugin = ReshapePlugin::new(j_item, Approach::SplitByRecords, true);
        let t0 = Instant::now();
        Execution::start_with_plugin(f.workflow, cfg, Box::new(plugin)).join();
        let on = t0.elapsed().as_secs_f64();
        println!(
            "{rows:>8} {off:>12.2} {on:>12.2} {:>8.1}%",
            (on / off - 1.0) * 100.0
        );
    }
    println!("(paper: 1–2% across configurations)\n");
}

/// Table 3.2: Reshape on sort.
fn tab3_2_sort() {
    println!("--- Table 3.2: Reshape on sort (W3) ---");
    println!(
        "{:>8} {:>8} {:>8} {:>8} {:>10}",
        "workers", "minLBR", "medLBR", "maxLBR", "time (s)"
    );
    for workers in [4usize, 8] {
        let f = texera_amber::flows::orders_sort_costed(2.0, workers, 4_000);
        let sort = f.focus;
        let cfg = Config {
            batch_size: 64,
            data_queue_cap: 64,
            reshape_eta: 50.0,
            reshape_tau: 50.0,
            ..Config::default()
        };
        let plugin = ReshapePlugin::new(sort, Approach::SplitByRecords, false);
        let report = plugin.report();
        let t0 = Instant::now();
        let exec = Execution::start_with_plugin(f.workflow, cfg, Box::new(plugin));
        let summary = exec.join();
        let elapsed = t0.elapsed().as_secs_f64();
        let rep = report.lock().unwrap();
        let mut s = Summary::new();
        for (_, skewed, helpers) in rep.mitigations.iter() {
            let get = |idx: usize| {
                summary
                    .worker_stats
                    .iter()
                    .find(|(id, _)| id.op == sort && id.idx == idx)
                    .map(|(_, st)| st.processed as f64)
                    .unwrap_or(0.0)
            };
            let (a, b) = (get(*skewed), get(helpers[0]));
            if a.max(b) > 0.0 {
                s.record(a.min(b) / a.max(b));
            }
        }
        if s.is_empty() {
            println!(
                "{workers:>8} {:>8} {:>8} {:>8} {elapsed:>10.2} (no mitigation fired)",
                "-", "-", "-"
            );
        } else {
            println!(
                "{workers:>8} {:>8.2} {:>8.2} {:>8.2} {elapsed:>10.2}",
                s.min(),
                s.percentile(50.0),
                s.max()
            );
        }
    }
    println!("(paper: ratios 0.83–0.95 across 20–80 workers; ~20% faster end-to-end)\n");
}

/// Fig. 3.26: multiple helpers — the skewed worker's residual load
/// falls as helpers are added (until migration costs dominate).
fn fig3_26_multi_helpers() {
    println!("--- Fig 3.26: multiple helper workers ---");
    println!("{:>8} {:>16} {:>14}", "helpers", "CA worker load", "load reduction");
    let total = 100_000;
    let workers = 8;
    let ca_worker = worker_of_key(tweets::CA as i64, workers);
    let load_of = |summary: &ExecSummary, join: usize| {
        summary
            .worker_stats
            .iter()
            .find(|(id, _)| id.op == join && id.idx == ca_worker)
            .map(|(_, s)| s.processed)
            .unwrap_or(0)
    };
    // Unmitigated baseline.
    let f = tweet_join_costed(total, workers, 0xC0FFEE, PROBE_COST);
    let join = f.focus;
    let summary = Execution::start(f.workflow, skew_cfg()).join();
    let base_load = load_of(&summary, join);
    println!("{:>8} {base_load:>16} {:>14}", 0, "-");
    for helpers in [1usize, 2, 4] {
        let f = tweet_join_costed(total, workers, 0xC0FFEE, PROBE_COST);
        let join = f.focus;
        let cfg = Config { reshape_max_helpers: helpers, ..skew_cfg() };
        let plugin = ReshapePlugin::new(join, Approach::SplitByRecords, true);
        let exec = Execution::start_with_plugin(f.workflow, cfg, Box::new(plugin));
        let summary = exec.join();
        let load = load_of(&summary, join);
        println!(
            "{helpers:>8} {load:>16} {:>14}",
            base_load.saturating_sub(load)
        );
    }
    println!("(paper: LR rises 13M → ~19.7M then falls as migration time grows)\n");
}

/// Fig. 3.27: metric-independence (the Flink port used busy-time).
fn fig3_27_alt_metric() {
    println!("--- Fig 3.27: busy-time metric (Flink-style config) ---");
    let total = 100_000;
    let workers = 8;
    let f = tweet_join_costed(total, workers, 0xC0FFEE, PROBE_COST);
    let join = f.focus;
    let cfg = Config {
        reshape_metric: WorkloadMetric::BusyTime,
        reshape_busy_threshold: 0.5,
        ..skew_cfg()
    };
    let plugin = ReshapePlugin::new(join, Approach::SplitByRecords, true);
    let pairs = PairsHandle::Report(plugin.report());
    let exec = Execution::start_with_plugin(f.workflow, cfg, Box::new(plugin));
    let summary = exec.join();
    println!(
        "busy-time metric: {} mitigation(s); CA-pair LBR {:.2}; run {:.2}s",
        pairs.pairs().len(),
        ca_lbr(&summary, join, workers, &pairs),
        summary.elapsed.as_secs_f64()
    );
    println!("(paper: Flink port reaches LBR ≈ 0.9)\n");
}

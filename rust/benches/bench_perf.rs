//! Performance microbenchmarks (§Perf of EXPERIMENTS.md): the engine's
//! hot-path numbers — tuple throughput vs batch size, routing cost,
//! control-path latency, PJRT classifier throughput.
//!
//! ```text
//! cargo bench --bench bench_perf
//! ```

use std::time::Instant;

use texera_amber::config::Config;
use texera_amber::engine::{Execution, OpSpec, PartitionScheme, Workflow};
use texera_amber::operators::basic::{Cmp, Filter};
use texera_amber::operators::{CollectSink, SinkHandle};
use texera_amber::engine::partitioner::{PartitionScheme as PS, Partitioner};
use texera_amber::tuple::{Tuple, Value};
use texera_amber::workloads::{TupleSource, VecSource};

fn main() {
    println!("=== bench_perf: hot-path microbenchmarks ===\n");
    throughput_vs_batch_size();
    routing_cost();
    pause_latency();
    pjrt_classifier_throughput();
}

fn pipeline(total: usize, workers: usize, batch: usize) -> f64 {
    let mut w = Workflow::new();
    let scan = w.add(OpSpec::source("scan", workers, move |idx, parts| {
        let rows: Vec<Tuple> = (0..total)
            .skip(idx)
            .step_by(parts)
            .map(|i| Tuple::new(vec![Value::Int(i as i64)]))
            .collect();
        Box::new(VecSource::new(rows)) as Box<dyn TupleSource>
    }));
    let filter = w.add(OpSpec::unary("filter", workers, PartitionScheme::RoundRobin, |_, _| {
        Box::new(Filter::new(0, Cmp::Ge, Value::Int(0)))
    }));
    let handle = SinkHandle::new(0);
    let h = handle.clone();
    let sink = w.add(OpSpec::unary("sink", 1, PartitionScheme::RoundRobin, move |_, _| {
        Box::new(CollectSink::new(h.clone()))
    }));
    w.connect(scan, filter, 0);
    w.connect(filter, sink, 0);
    let cfg = Config { batch_size: batch, ..Config::default() };
    let t0 = Instant::now();
    Execution::start(w, cfg).join();
    total as f64 / t0.elapsed().as_secs_f64()
}

/// Engine throughput vs batch size (scan→filter→sink, 2 workers).
fn throughput_vs_batch_size() {
    println!("--- engine throughput vs batch size ---");
    println!("{:>8} {:>16}", "batch", "ktuples/s");
    let total = 1_000_000;
    for batch in [16usize, 64, 200, 400, 1600, 6400] {
        // Warm + measure best of 2 (1-core noise).
        let a = pipeline(total, 2, batch);
        let b = pipeline(total, 2, batch);
        println!("{batch:>8} {:>16.0}", a.max(b) / 1e3);
    }
    println!();
}

/// Partitioner routing nanoseconds per tuple.
fn routing_cost() {
    println!("--- partitioner routing cost ---");
    let t = Tuple::new(vec![Value::Int(123456)]);
    for (name, scheme) in [
        ("hash", PS::Hash { key: 0 }),
        ("round-robin", PS::RoundRobin),
        (
            "range",
            PS::Range {
                key: 0,
                bounds: (1..16).map(|i| Value::Int(i * 1000)).collect(),
            },
        ),
    ] {
        let mut p = Partitioner::new(scheme, 16, 0);
        let n = 3_000_000u64;
        let t0 = Instant::now();
        let mut acc = 0usize;
        for _ in 0..n {
            acc = acc.wrapping_add(p.route(&t));
        }
        let ns = t0.elapsed().as_nanos() as f64 / n as f64;
        println!("{name:>12}: {ns:>6.1} ns/tuple (acc {acc})");
    }
    println!();
}

/// Pause round-trip latency on an active pipeline.
fn pause_latency() {
    println!("--- pause/resume latency (active 8-worker pipeline) ---");
    let total = 4_000_000;
    let mut w = Workflow::new();
    let scan = w.add(OpSpec::source("scan", 2, move |idx, parts| {
        let rows: Vec<Tuple> = (0..total)
            .skip(idx)
            .step_by(parts)
            .map(|i| Tuple::new(vec![Value::Int(i as i64)]))
            .collect();
        Box::new(VecSource::new(rows)) as Box<dyn TupleSource>
    }));
    let filter = w.add(OpSpec::unary("filter", 8, PartitionScheme::RoundRobin, |_, _| {
        Box::new(Filter::new(0, Cmp::Ge, Value::Int(0)))
    }));
    let handle = SinkHandle::new(0);
    let h = handle.clone();
    let sink = w.add(OpSpec::unary("sink", 1, PartitionScheme::RoundRobin, move |_, _| {
        Box::new(CollectSink::new(h.clone()))
    }));
    w.connect(scan, filter, 0);
    w.connect(filter, sink, 0);
    let exec = Execution::start(w, Config::default());
    let mut s = texera_amber::metrics::Summary::new();
    for _ in 0..20 {
        std::thread::sleep(std::time::Duration::from_millis(5));
        s.record(exec.pause().as_secs_f64() * 1e6);
        exec.resume();
    }
    exec.join();
    println!(
        "p50 {:.0} µs | p99 {:.0} µs | max {:.0} µs\n",
        s.percentile(50.0),
        s.percentile(99.0),
        s.max()
    );
}

/// PJRT classifier throughput (L1/L2 artifact through the runtime).
fn pjrt_classifier_throughput() {
    println!("--- PJRT classifier throughput ---");
    if !texera_amber::runtime::pjrt::artifact_exists("artifacts", "classifier") {
        println!("skipped: run `make artifacts` first\n");
        return;
    }
    use texera_amber::operators::ml_infer::{BATCH, TOKENS};
    use texera_amber::runtime::{InferenceServer, Tensor};
    let server = InferenceServer::start("artifacts");
    let h = server.handle();
    let tokens = vec![7i32; BATCH * TOKENS];
    // Warm-up compiles the executable.
    h.run("classifier", vec![Tensor::I32(tokens.clone(), vec![BATCH as i64, TOKENS as i64])])
        .expect("inference");
    let n = 200;
    let t0 = Instant::now();
    for _ in 0..n {
        h.run("classifier", vec![Tensor::I32(tokens.clone(), vec![BATCH as i64, TOKENS as i64])])
            .expect("inference");
    }
    let per_batch = t0.elapsed().as_secs_f64() / n as f64;
    println!(
        "kernel (one-hot, TPU-shaped): {:.2} ms/batch → {:.0} tuples/s",
        per_batch * 1e3,
        BATCH as f64 / per_batch
    );
    // The CPU-tuned gather export (§Perf L2 iteration); identical math.
    if texera_amber::runtime::pjrt::artifact_exists("artifacts", "classifier_cpu") {
        h.run(
            "classifier_cpu",
            vec![Tensor::I32(tokens.clone(), vec![BATCH as i64, TOKENS as i64])],
        )
        .expect("inference");
        let t0 = Instant::now();
        for _ in 0..n {
            h.run(
                "classifier_cpu",
                vec![Tensor::I32(tokens.clone(), vec![BATCH as i64, TOKENS as i64])],
            )
            .expect("inference");
        }
        let pb = t0.elapsed().as_secs_f64() / n as f64;
        println!(
            "classifier_cpu (gather):      {:.2} ms/batch → {:.0} tuples/s ({:.1}x)",
            pb * 1e3,
            BATCH as f64 / pb,
            per_batch / pb
        );
    }
    println!();
}

//! Performance microbenchmarks (§Perf of EXPERIMENTS.md): the engine's
//! hot-path numbers — tuple throughput vs batch size, hash-shuffle
//! (exchange) throughput, scatter micro old-vs-new, row-vs-columnar
//! data plane, SPSC exchange-lane throughput, routing cost,
//! control-path latency, PJRT classifier throughput.
//!
//! ```text
//! cargo bench --bench bench_perf            # full run
//! cargo bench --bench bench_perf -- --smoke # CI smoke (small totals)
//! ```
//!
//! Results land in `BENCH_perf.json` at the repository root (falling
//! back to the crate dir when run elsewhere), so the perf trajectory
//! is tracked across PRs; the file's full schema — every section,
//! field meanings and units — is documented in `docs/BENCH.md`. The
//! per-tuple exchange path is retained as
//! `Partitioner::route_with_base`, so "old vs new" is re-measured live
//! on every run rather than pinned to stale numbers. The `maestro`
//! section compares a static region schedule against the elastic,
//! observation-driven one (per-region worker budget + re-planning);
//! the `source_scale` section measures a mid-run 2→4 scale-up of a
//! **source** operator (universal elasticity: splittable scan ranges)
//! on a source-heavy skewed workflow; the `migration` section measures
//! throughput before/during/after each live plan-migration delta kind
//! (repartition swap, mat insert, mat insert+remove, worker re-plan)
//! plus each delta's fence duration; the `spill` section measures
//! group-by throughput as resident state grows past the memory budget
//! (state at 0.5x/2x/8x of the budget, budgets derived from the
//! unbounded run's high-water) plus recovery time from an automatic
//! checkpoint whose manifest includes spilled partitions.

use std::time::{Duration, Instant};

use texera_amber::config::Config;
use texera_amber::engine::{
    Execution, Fault, FaultPlan, OpSpec, PartitionScheme, PlanDelta, WorkerId, Workflow,
};
use texera_amber::maestro::cost::CostParams;
use texera_amber::maestro::MaestroScheduler;
use texera_amber::operators::basic::{Cmp, Filter, MapUdf};
use texera_amber::operators::group_by::{AggKind, GroupByFinal, GroupByPartial};
use texera_amber::operators::{CollectSink, CountByKeySink, HashJoin, SinkHandle};
use texera_amber::engine::partitioner::{
    hash_column, PartitionScheme as PS, Partitioner, RouteVec,
};
use texera_amber::tuple::{Tuple, TupleBatch, Value};
use texera_amber::workloads::{TupleSource, VecSource};

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    println!(
        "=== bench_perf: hot-path microbenchmarks{} ===\n",
        if smoke { " (smoke)" } else { "" }
    );
    let (rows, baseline) = throughput_vs_batch_size(smoke);
    let shuffle = shuffle_section(smoke);
    let micro = scatter_micro_section(smoke);
    let rvc = row_vs_columnar_section(smoke);
    let lanes = lanes_section(smoke);
    let elastic = elastic_scaling(smoke);
    let source_scale = source_scale_section(smoke);
    let migration = migration_section(smoke);
    let maestro = maestro_section(smoke);
    let faults = faults_section(smoke);
    let spill = spill_section(smoke);
    let service = service_section(smoke);
    if smoke {
        // Smoke totals are not trajectory-quality numbers: exercise
        // the sections but leave the recorded BENCH_perf.json alone.
        println!("(smoke: BENCH_perf.json not written)");
    } else {
        write_bench_json(
            &rows,
            baseline,
            &elastic,
            &source_scale,
            &migration,
            &shuffle,
            &micro,
            &rvc,
            &lanes,
            &maestro,
            &faults,
            &spill,
            &service,
        );
        routing_cost();
        pause_latency();
        pjrt_classifier_throughput();
    }
}

/// One scan→filter→sink run; returns tuples/second. `ctrl_interval`
/// is the DP chunk length: 1 reproduces the old per-tuple emit path
/// (one `process` dispatch + one route per tuple), larger values
/// exercise the batch-at-a-time plane. `columnar` toggles the
/// struct-of-arrays data plane (typed column batches + column kernels)
/// vs the row-major layout on identical plans.
fn pipeline_cfg(
    total: usize,
    workers: usize,
    batch: usize,
    ctrl_interval: usize,
    columnar: bool,
) -> f64 {
    let mut w = Workflow::new();
    let scan = w.add(OpSpec::source("scan", workers, move |idx, parts| {
        let rows: Vec<Tuple> = (0..total)
            .skip(idx)
            .step_by(parts)
            .map(|i| Tuple::new(vec![Value::Int(i as i64)]))
            .collect();
        Box::new(VecSource::new(rows)) as Box<dyn TupleSource>
    }));
    let filter = w.add(OpSpec::unary("filter", workers, PartitionScheme::RoundRobin, |_, _| {
        Box::new(Filter::new(0, Cmp::Ge, Value::Int(0)))
    }));
    let handle = SinkHandle::new(0);
    let h = handle.clone();
    let sink = w.add(OpSpec::unary("sink", 1, PartitionScheme::RoundRobin, move |_, _| {
        Box::new(CollectSink::new(h.clone()))
    }));
    w.connect(scan, filter, 0);
    w.connect(filter, sink, 0);
    let cfg = Config {
        batch_size: batch,
        ctrl_check_interval: ctrl_interval,
        columnar,
        ..Config::default()
    };
    let t0 = Instant::now();
    Execution::start(w, cfg).join();
    total as f64 / t0.elapsed().as_secs_f64()
}

fn pipeline(total: usize, workers: usize, batch: usize, ctrl_interval: usize) -> f64 {
    pipeline_cfg(total, workers, batch, ctrl_interval, true)
}

/// Engine throughput vs batch size (scan→filter→sink, 2 workers).
/// Row `batch=1` is the old per-tuple emit path (every tuple is its
/// own message, chunk length 1); the other rows chunk at the batch
/// size. Results land in BENCH_perf.json so the perf trajectory is
/// tracked across PRs.
fn throughput_vs_batch_size(smoke: bool) -> (Vec<(usize, usize, f64)>, f64) {
    println!("--- engine throughput vs batch size ---");
    println!("{:>8} {:>10} {:>16} {:>10}", "batch", "interval", "ktuples/s", "vs b=1");
    let total = if smoke { 100_000 } else { 1_000_000 };
    let batches: &[usize] = if smoke {
        &[1, 400, 1024]
    } else {
        &[1, 16, 64, 200, 400, 1024, 6400]
    };
    let mut rows: Vec<(usize, usize, f64)> = Vec::new();
    let mut baseline = 0.0f64;
    for &batch in batches {
        // Per-tuple baseline uses chunk length 1; batch rows chunk at
        // the batch size (bounded pause latency either way).
        let interval = if batch == 1 { 1 } else { batch };
        // Warm + measure best of 2 (1-core noise).
        let a = pipeline(total, 2, batch, interval);
        let b = pipeline(total, 2, batch, interval);
        let best = a.max(b);
        if batch == 1 {
            baseline = best;
        }
        let speedup = if baseline > 0.0 { best / baseline } else { 1.0 };
        println!(
            "{batch:>8} {interval:>10} {:>16.0} {speedup:>9.1}x",
            best / 1e3
        );
        rows.push((batch, interval, best));
    }
    println!();
    (rows, baseline)
}

/// One hash-shuffle measurement: distribution × batch size → tuples/s.
struct ShuffleRow {
    dist: &'static str,
    batch: usize,
    tps: f64,
}

/// End-to-end hash shuffle: scan(2 workers) ──Hash(key)──▶ count-sink
/// (4 workers). The edge crosses the vectorized exchange; the sink
/// costs two atomic adds per batch, so the shuffle dominates.
/// `skewed` puts 90% of tuples on one hot key (plus 100 cold keys);
/// uniform cycles 512 keys.
fn shuffle_tps(total: usize, batch: usize, skewed: bool) -> f64 {
    shuffle_tps_cfg(total, batch, skewed, true)
}

fn shuffle_tps_cfg(total: usize, batch: usize, skewed: bool, columnar: bool) -> f64 {
    let mut w = Workflow::new();
    let scan = w.add(OpSpec::source("scan", 2, move |idx, parts| {
        let rows: Vec<Tuple> = (0..total)
            .skip(idx)
            .step_by(parts)
            .map(|i| {
                let key = if skewed {
                    if i % 10 != 0 { 0 } else { (i % 100) as i64 + 1 }
                } else {
                    (i % 512) as i64
                };
                Tuple::new(vec![Value::Int(key)])
            })
            .collect();
        Box::new(VecSource::new(rows)) as Box<dyn TupleSource>
    }));
    let handle = SinkHandle::new(512);
    let h = handle.clone();
    let sink = w.add(OpSpec::unary(
        "count_sink",
        4,
        PartitionScheme::Hash { key: 0 },
        move |_, _| Box::new(CountByKeySink::new(h.clone(), 0)),
    ));
    w.connect(scan, sink, 0);
    let cfg = Config {
        batch_size: batch,
        ctrl_check_interval: batch.max(1),
        columnar,
        ..Config::default()
    };
    let t0 = Instant::now();
    Execution::start(w, cfg).join();
    let elapsed = t0.elapsed().as_secs_f64();
    assert_eq!(handle.total() as usize, total, "shuffle dropped tuples");
    total as f64 / elapsed
}

/// Hash-shuffle tuples/s at batch 1/32/1024, uniform and skewed —
/// recorded in BENCH_perf.json (the acceptance row for the exchange
/// rework is skewed @ batch 1024).
fn shuffle_section(smoke: bool) -> Vec<ShuffleRow> {
    println!("--- hash-shuffle throughput (scan(2) --Hash--> count-sink(4)) ---");
    println!("{:>8} {:>8} {:>16}", "dist", "batch", "ktuples/s");
    let total = if smoke { 60_000 } else { 1_000_000 };
    let mut rows = Vec::new();
    for &(dist, skewed) in &[("uniform", false), ("skewed", true)] {
        for &batch in &[1usize, 32, 1024] {
            // Warm + measure best of 2 (1-core noise).
            let a = shuffle_tps(total, batch, skewed);
            let b = shuffle_tps(total, batch, skewed);
            let best = a.max(b);
            println!("{dist:>8} {batch:>8} {:>16.0}", best / 1e3);
            rows.push(ShuffleRow { dist, batch, tps: best });
        }
    }
    println!();
    rows
}

/// Old-vs-new exchange inner loop on identical data: (per-tuple
/// `route_with_base` tuples/s, `hash_column` + `route_batch` tuples/s).
struct ScatterMicro {
    uniform: (f64, f64),
    skewed: (f64, f64),
}

fn scatter_micro(skewed: bool, rounds: usize) -> (f64, f64) {
    let receivers = 16usize;
    let batch: TupleBatch = (0..1024usize)
        .map(|i| {
            let key = if skewed {
                if i % 10 != 0 { 0 } else { (i % 100) as i64 + 1 }
            } else {
                i as i64
            };
            Tuple::new(vec![Value::Int(key)])
        })
        .collect();
    let mut p = Partitioner::new(PS::Hash { key: 0 }, receivers, 0);
    let mut acc = 0usize;
    // Old inner loop: one route (one hash) per tuple.
    let t0 = Instant::now();
    for _ in 0..rounds {
        for t in batch.iter() {
            let (b, d) = p.route_with_base(t);
            acc = acc.wrapping_add(b + d + 1);
        }
    }
    let per_tuple_tps = (rounds * batch.len()) as f64 / t0.elapsed().as_secs_f64();
    // New inner loop: hash column + selection vectors, scratch reused.
    let mut hashes: Vec<u64> = Vec::new();
    let mut routes = RouteVec::default();
    let t1 = Instant::now();
    for _ in 0..rounds {
        hash_column(&batch, 0, &mut hashes);
        p.route_batch(&batch, &hashes, &mut routes);
        acc = acc.wrapping_add(routes.sel.iter().map(Vec::len).sum::<usize>());
    }
    let batch_tps = (rounds * batch.len()) as f64 / t1.elapsed().as_secs_f64();
    // Keep `acc` observable so the loops cannot be optimized away.
    assert!(acc > 0);
    (per_tuple_tps, batch_tps)
}

fn scatter_micro_section(smoke: bool) -> ScatterMicro {
    println!("--- scatter micro: route_with_base (old) vs route_batch (new), 1024-tuple batches, 16 receivers ---");
    let rounds = if smoke { 500 } else { 5_000 };
    let micro = ScatterMicro {
        uniform: scatter_micro(false, rounds),
        skewed: scatter_micro(true, rounds),
    };
    for (name, (old, new)) in [("uniform", micro.uniform), ("skewed", micro.skewed)] {
        println!(
            "{name:>8}: per-tuple {:>9.0} ktuples/s | batch {:>9.0} ktuples/s | {:.2}x",
            old / 1e3,
            new / 1e3,
            new / old
        );
    }
    println!();
    micro
}

/// Row-major vs columnar data plane on identical plans: filter
/// pipeline and skewed hash shuffle, both at batch 1024.
struct RowVsColumnar {
    pipeline_row_tps: f64,
    pipeline_col_tps: f64,
    shuffle_row_tps: f64,
    shuffle_col_tps: f64,
}

/// `Config::columnar` off vs on: the same scan→filter→sink pipeline
/// and the same skewed hash shuffle, so the delta isolates the
/// struct-of-arrays layout (typed column kernels in operators, shipped
/// hash columns and gather-based scatter in the exchange) against the
/// row-at-a-time layout. Recorded in BENCH_perf.json; the acceptance
/// row for the columnar rework is the shuffle speedup.
fn row_vs_columnar_section(smoke: bool) -> RowVsColumnar {
    println!("--- row vs columnar data plane (batch 1024) ---");
    let total = if smoke { 100_000 } else { 1_000_000 };
    let best = |f: &dyn Fn() -> f64| {
        let a = f();
        let b = f();
        a.max(b)
    };
    let pipeline_row = best(&|| pipeline_cfg(total, 2, 1024, 1024, false));
    let pipeline_col = best(&|| pipeline_cfg(total, 2, 1024, 1024, true));
    let shuffle_row = best(&|| shuffle_tps_cfg(total, 1024, true, false));
    let shuffle_col = best(&|| shuffle_tps_cfg(total, 1024, true, true));
    for (name, row, col) in [
        ("filter pipeline", pipeline_row, pipeline_col),
        ("skewed shuffle", shuffle_row, shuffle_col),
    ] {
        println!(
            "{name:>16}: row {:>9.0} ktuples/s | columnar {:>9.0} ktuples/s | {:.2}x",
            row / 1e3,
            col / 1e3,
            col / row
        );
    }
    println!();
    RowVsColumnar {
        pipeline_row_tps: pipeline_row,
        pipeline_col_tps: pipeline_col,
        shuffle_row_tps: shuffle_row,
        shuffle_col_tps: shuffle_col,
    }
}

/// SPSC exchange-lane throughput: N producer threads, one consumer.
struct LanesBench {
    senders_1_tps: f64,
    senders_4_tps: f64,
}

/// Raw data-ring throughput through the per-sender SPSC lanes: each
/// producer thread owns a private bounded lane into one consumer
/// (cloning the sender registers a fresh lane), so producers never
/// serialize on each other — the multi-producer row measures exactly
/// that.
fn lanes_tps(senders: usize, batches_per_sender: usize) -> f64 {
    use texera_amber::engine::channel::mailbox;
    use texera_amber::engine::message::DataMessage;
    use texera_amber::engine::{DataEvent, WorkerId};
    let (tx, mbox) = mailbox(64);
    let batch: TupleBatch = (0..1024usize)
        .map(|i| Tuple::new(vec![Value::Int(i as i64)]))
        .collect();
    let total_tuples = senders * batches_per_sender * batch.len();
    let t0 = Instant::now();
    let mut producers = Vec::new();
    for s in 0..senders {
        let tx = tx.clone();
        let batch = batch.clone();
        producers.push(std::thread::spawn(move || {
            for seq in 0..batches_per_sender {
                let msg = DataMessage {
                    from: WorkerId::new(0, s),
                    port: 0,
                    seq: seq as u64,
                    batch: batch.clone(),
                    hashes: None,
                };
                tx.send(DataEvent::Batch(msg)).expect("receiver alive");
            }
        }));
    }
    drop(tx);
    let mut got = 0usize;
    while let Ok(ev) = mbox.data.recv() {
        if let DataEvent::Batch(m) = ev {
            got += m.batch.len();
        }
    }
    for p in producers {
        p.join().expect("producer thread");
    }
    assert_eq!(got, total_tuples, "lanes bench dropped events");
    total_tuples as f64 / t0.elapsed().as_secs_f64()
}

fn lanes_section(smoke: bool) -> LanesBench {
    println!("--- SPSC exchange lanes: 1024-tuple batches through the data ring ---");
    let batches = if smoke { 500 } else { 5_000 };
    let one = lanes_tps(1, batches);
    let four = lanes_tps(4, batches);
    println!(
        "1 sender: {:>9.0} ktuples/s | 4 senders: {:>9.0} ktuples/s ({:.2}x aggregate)",
        one / 1e3,
        four / 1e3,
        four / one
    );
    println!();
    LanesBench { senders_1_tps: one, senders_4_tps: four }
}

/// Elastic-scaling result: throughput of the scaled operator before and
/// after a mid-run 2→4 scale-up, plus the fence duration.
struct ElasticBench {
    workers_before: usize,
    workers_after: usize,
    before_tps: f64,
    after_tps: f64,
    fence_ms: f64,
}

/// Mid-run 2→4 scale-up on a skewed group-by workload (90% of tuples
/// hit one hot key; the partial layer carries a latency-bound per-tuple
/// cost, the paper's expensive-UDF shape, so added workers absorb it
/// even on one core). Throughput is the partial layer's processed rate
/// over a fixed window before vs. after the scale.
fn elastic_scaling(smoke: bool) -> ElasticBench {
    println!("--- elastic scaling: mid-run 2->4 scale-up (skewed group-by) ---");
    // Smoke keeps the fence + rewire path exercised but shrinks the
    // deliberately-throttled workload and measurement windows.
    let total = if smoke { 30_000usize } else { 150_000 };
    const COST_NS: u64 = 40_000;
    let mut w = Workflow::new();
    let scan = w.add(OpSpec::source("scan", 2, move |idx, parts| {
        let rows: Vec<Tuple> = (0..total)
            .skip(idx)
            .step_by(parts)
            .map(|i| {
                // 90% hot key 0, the rest spread over 100 keys.
                let key = if i % 10 != 0 { 0 } else { (i % 100) as i64 + 1 };
                Tuple::new(vec![Value::Int(key), Value::Int(1)])
            })
            .collect();
        Box::new(VecSource::new(rows)) as Box<dyn TupleSource>
    }));
    let partial = w.add(OpSpec::unary(
        "gb_partial",
        2,
        PartitionScheme::RoundRobin,
        |_, _| Box::new(GroupByPartial::new(0, 1, AggKind::Sum).with_cost(COST_NS)),
    ));
    let fin = w.add(
        OpSpec::unary("gb_final", 2, PartitionScheme::Hash { key: 0 }, |_, _| {
            Box::new(GroupByFinal::new(AggKind::Sum))
        })
        .with_blocking(vec![0]),
    );
    let handle = SinkHandle::new(0);
    let h = handle.clone();
    let sink = w.add(OpSpec::unary("sink", 1, PartitionScheme::RoundRobin, move |_, _| {
        Box::new(CollectSink::new(h.clone()))
    }));
    w.connect(scan, partial, 0);
    w.connect(partial, fin, 0);
    w.connect(fin, sink, 0);
    let cfg = Config {
        batch_size: 400,
        // Chunked control checks: the artificial cost sleeps once per
        // 64-tuple chunk, so sleep granularity doesn't distort rates.
        ctrl_check_interval: 64,
        ..Config::default()
    };
    let exec = Execution::start(w, cfg);
    let processed = |exec: &Execution| -> u64 {
        exec.stats()
            .iter()
            .filter(|(id, _)| id.op == partial)
            .map(|(_, s)| s.processed)
            .sum()
    };
    let window = Duration::from_millis(if smoke { 150 } else { 400 });
    std::thread::sleep(Duration::from_millis(if smoke { 40 } else { 100 })); // warm-up
    let p0 = processed(&exec);
    std::thread::sleep(window);
    let p1 = processed(&exec);
    let before_tps = (p1 - p0) as f64 / window.as_secs_f64();
    let fence = exec.scale_operator(partial, 4);
    let p2 = processed(&exec);
    std::thread::sleep(window);
    let p3 = processed(&exec);
    let after_tps = (p3 - p2) as f64 / window.as_secs_f64();
    exec.join();
    let speedup = if before_tps > 0.0 { after_tps / before_tps } else { 0.0 };
    println!(
        "2 workers: {:.0} tuples/s | 4 workers: {:.0} tuples/s | {speedup:.2}x | fence {:.1} ms",
        before_tps,
        after_tps,
        fence.as_secs_f64() * 1e3
    );
    println!("(sink groups: {})\n", handle.tuples().len());
    ElasticBench {
        workers_before: 2,
        workers_after: 4,
        before_tps,
        after_tps,
        fence_ms: fence.as_secs_f64() * 1e3,
    }
}

/// Source-scale result: scan-layer throughput before and after a
/// mid-run 2→4 *source* scale-up (universal elasticity), plus the
/// fence duration.
struct SourceScaleBench {
    workers_before: usize,
    workers_after: usize,
    before_tps: f64,
    after_tps: f64,
    fence_ms: f64,
}

/// Mid-run 2→4 scale-up of a **source** operator on a source-heavy
/// skewed workflow: the scan carries a latency-bound per-tuple parse
/// cost (the expensive-ingest shape) and feeds a cheap skewed group-by,
/// so the scan layer is the bottleneck and splitting its scan ranges
/// across more workers absorbs it. Throughput is the scan layer's
/// processed rate over a fixed window before vs. after the scale —
/// the formerly refusal-only path this PR's tentpole opens.
fn source_scale_section(smoke: bool) -> SourceScaleBench {
    println!("--- source scaling: mid-run 2->4 scan scale-up (source-heavy skewed workflow) ---");
    let total = if smoke { 30_000usize } else { 150_000 };
    const PARSE_COST_NS: u64 = 40_000;
    let mut w = Workflow::new();
    let scan = w.add(OpSpec::source_with_op(
        "scan",
        2,
        move |idx, parts| {
            let rows: Vec<Tuple> = (0..total)
                .skip(idx)
                .step_by(parts)
                .map(|i| {
                    // 90% hot key 0, the rest spread over 100 keys.
                    let key = if i % 10 != 0 { 0 } else { (i % 100) as i64 + 1 };
                    Tuple::new(vec![Value::Int(key), Value::Int(1)])
                })
                .collect();
            Box::new(VecSource::new(rows)) as Box<dyn TupleSource>
        },
        |_, _| Box::new(MapUdf::identity(PARSE_COST_NS)),
    ));
    let fin = w.add(
        OpSpec::unary("gb_final", 2, PartitionScheme::Hash { key: 0 }, |_, _| {
            Box::new(GroupByFinal::new(AggKind::Sum))
        })
        .with_blocking(vec![0]),
    );
    let handle = SinkHandle::new(0);
    let h = handle.clone();
    let sink = w.add(OpSpec::unary("sink", 1, PartitionScheme::RoundRobin, move |_, _| {
        Box::new(CollectSink::new(h.clone()))
    }));
    w.connect(scan, fin, 0);
    w.connect(fin, sink, 0);
    let cfg = Config {
        batch_size: 400,
        // Chunked control checks: the artificial parse cost sleeps once
        // per 64-tuple chunk, so sleep granularity doesn't distort
        // rates.
        ctrl_check_interval: 64,
        ..Config::default()
    };
    let exec = Execution::start(w, cfg);
    let processed = |exec: &Execution| -> u64 {
        exec.stats()
            .iter()
            .filter(|(id, _)| id.op == scan)
            .map(|(_, s)| s.processed)
            .sum()
    };
    let window = Duration::from_millis(if smoke { 150 } else { 400 });
    std::thread::sleep(Duration::from_millis(if smoke { 40 } else { 100 })); // warm-up
    let p0 = processed(&exec);
    std::thread::sleep(window);
    let p1 = processed(&exec);
    let before_tps = (p1 - p0) as f64 / window.as_secs_f64();
    let fence = exec.scale_operator(scan, 4);
    let p2 = processed(&exec);
    std::thread::sleep(window);
    let p3 = processed(&exec);
    let after_tps = (p3 - p2) as f64 / window.as_secs_f64();
    exec.join();
    let speedup = if before_tps > 0.0 { after_tps / before_tps } else { 0.0 };
    println!(
        "2 scan workers: {:.0} tuples/s | 4 scan workers: {:.0} tuples/s | {speedup:.2}x | fence {:.1} ms",
        before_tps,
        after_tps,
        fence.as_secs_f64() * 1e3
    );
    println!("(sink groups: {})\n", handle.tuples().len());
    SourceScaleBench {
        workers_before: 2,
        workers_after: 4,
        before_tps,
        after_tps,
        fence_ms: fence.as_secs_f64() * 1e3,
    }
}

/// Live-migration result for one delta kind: throughput of the
/// downstream (filter) layer before the delta, during the window
/// spanning `Execution::migrate` itself (which contains the fence
/// stall), and after — plus the summed fence duration the planner
/// reports.
struct MigrationBench {
    kind: &'static str,
    applied: bool,
    before_tps: f64,
    during_tps: f64,
    after_tps: f64,
    fence_ms: f64,
}

/// Mid-run plan migrations on a source-heavy pipeline (scan with a
/// latency-bound 40µs parse cost → filter → sink): one fresh run per
/// delta kind — repartition-scheme swap on the live scan→filter edge,
/// live materialization insert (downstream goes quiet until the writer
/// completes and the reader activates — that dip is the honest cost of
/// the delta), insert followed by the measured *removal* (store drain +
/// re-injection through the restored edge), and a 2→4 worker re-plan.
fn migration_section(smoke: bool) -> Vec<MigrationBench> {
    println!("--- live plan migration: throughput before/during/after each delta kind ---");
    let total = if smoke { 30_000usize } else { 150_000 };
    const PARSE_COST_NS: u64 = 40_000;
    let window = Duration::from_millis(if smoke { 150 } else { 400 });
    let mut out = Vec::new();
    for kind in ["repartition", "mat_insert", "mat_remove", "replan"] {
        let mut w = Workflow::new();
        let scan = w.add(OpSpec::source_with_op(
            "scan",
            2,
            move |idx, parts| {
                let rows: Vec<Tuple> = (0..total)
                    .skip(idx)
                    .step_by(parts)
                    .map(|i| {
                        // 90% hot key 0, the rest spread over 100 keys.
                        let key = if i % 10 != 0 { 0 } else { (i % 100) as i64 + 1 };
                        Tuple::new(vec![Value::Int(key), Value::Int(1)])
                    })
                    .collect();
                Box::new(VecSource::new(rows)) as Box<dyn TupleSource>
            },
            |_, _| Box::new(MapUdf::identity(PARSE_COST_NS)),
        ));
        let filter = w.add(OpSpec::unary("filter", 2, PartitionScheme::RoundRobin, |_, _| {
            Box::new(Filter::new(1, Cmp::Ge, Value::Int(0)))
        }));
        let handle = SinkHandle::new(0);
        let h = handle.clone();
        let sink = w.add(OpSpec::unary("sink", 1, PartitionScheme::RoundRobin, move |_, _| {
            Box::new(CollectSink::new(h.clone()))
        }));
        w.connect(scan, filter, 0);
        w.connect(filter, sink, 0);
        let cfg = Config {
            batch_size: 400,
            // Chunked control checks: the parse cost sleeps once per
            // 64-tuple chunk, so sleep granularity doesn't distort
            // rates.
            ctrl_check_interval: 64,
            ..Config::default()
        };
        let exec = Execution::start(w, cfg);
        let processed = |exec: &Execution| -> u64 {
            exec.stats()
                .iter()
                .filter(|(id, _)| id.op == filter)
                .map(|(_, s)| s.processed)
                .sum()
        };
        std::thread::sleep(Duration::from_millis(if smoke { 40 } else { 100 })); // warm-up
        let p0 = processed(&exec);
        std::thread::sleep(window);
        let p1 = processed(&exec);
        let before_tps = (p1 - p0) as f64 / window.as_secs_f64();
        let delta = match kind {
            "repartition" => PlanDelta::Repartition {
                op: filter,
                port: 0,
                scheme: PartitionScheme::Hash { key: 0 },
            },
            "mat_insert" | "mat_remove" => {
                PlanDelta::InsertMat { from: scan, to: filter, to_port: 0 }
            }
            _ => PlanDelta::Replan { workers: vec![(filter, 4)] },
        };
        let t0 = Instant::now();
        let mut outcome = exec.migrate(delta);
        if kind == "mat_remove" && outcome.applied {
            // The measured delta is the removal of the just-inserted
            // mat: store drain + re-injection on the restored edge.
            outcome = exec.migrate(PlanDelta::RemoveMat { from: scan, to: filter, to_port: 0 });
        }
        let during = t0.elapsed().as_secs_f64().max(1e-9);
        let p2 = processed(&exec);
        let during_tps = (p2 - p1) as f64 / during;
        std::thread::sleep(window);
        let p3 = processed(&exec);
        let after_tps = (p3 - p2) as f64 / window.as_secs_f64();
        exec.join();
        let fence_ms = outcome.fence_total().as_secs_f64() * 1e3;
        println!(
            "{kind:>12}: before {before_tps:>8.0} t/s | during {during_tps:>8.0} t/s | after {after_tps:>8.0} t/s | fence {fence_ms:.1} ms{}",
            if outcome.applied { "" } else { " (refused)" }
        );
        out.push(MigrationBench {
            kind,
            applied: outcome.applied,
            before_tps,
            during_tps,
            after_tps,
            fence_ms,
        });
    }
    println!();
    out
}

/// Maestro static-vs-elastic schedule comparison on one skewed
/// multi-region workflow.
struct MaestroBench {
    rows: usize,
    budget: usize,
    static_frt_s: f64,
    static_total_s: f64,
    elastic_frt_s: f64,
    elastic_total_s: f64,
    replans: usize,
    scales_applied: usize,
}

/// The skewed multi-region workflow: one scan replicates into an
/// expensive build-side UDF chain (the paper's ML stand-in) and into
/// the probe of a strict join, so the region graph is cyclic and
/// Maestro must materialize a probe-path edge. The ancestor region
/// carries the UDF, so its completion time dominates the sink region's
/// first response time — exactly the lever per-region worker
/// assignment moves. Keys are 90% hot (key 0), the rest spread, with
/// rows `i < 64` carrying key `i` so the build side (`val < 64`) holds
/// one row per key and the join emits one tuple per probe row.
fn maestro_workflow(
    rows: usize,
    udf_cost_ns: u64,
) -> (Workflow, SinkHandle, usize, usize, usize) {
    let mut w = Workflow::new();
    let scan = w.add(OpSpec::source("scan", 2, move |idx, parts| {
        let data: Vec<Tuple> = (0..rows)
            .skip(idx)
            .step_by(parts)
            .map(|i| {
                let key = if i < 64 {
                    i as i64
                } else if i % 10 != 0 {
                    0
                } else {
                    (i % 64) as i64
                };
                Tuple::new(vec![Value::Int(key), Value::Int(i as i64)])
            })
            .collect();
        Box::new(VecSource::new(data)) as Box<dyn TupleSource>
    }));
    let udf = w.add(OpSpec::unary("udf_build", 2, PartitionScheme::RoundRobin, move |_, _| {
        Box::new(MapUdf::identity(udf_cost_ns))
    }));
    let buildf = w.add(OpSpec::unary("buildf", 2, PartitionScheme::RoundRobin, |_, _| {
        Box::new(Filter::new(1, Cmp::Lt, Value::Int(64)))
    }));
    let prep = w.add(OpSpec::unary("prep", 2, PartitionScheme::RoundRobin, |_, _| {
        Box::new(Filter::new(1, Cmp::Ge, Value::Int(0)))
    }));
    let join = w.add(OpSpec::binary(
        "join",
        2,
        [PartitionScheme::Hash { key: 0 }, PartitionScheme::Hash { key: 0 }],
        vec![0],
        |_, _| Box::new(HashJoin::new(0, 0).strict()),
    ));
    let handle = SinkHandle::new(0);
    let h = handle.clone();
    let sink = w.add(OpSpec::unary("sink", 1, PartitionScheme::RoundRobin, move |_, _| {
        Box::new(CollectSink::new(h.clone()))
    }));
    w.connect(scan, udf, 0);
    w.connect(udf, buildf, 0);
    w.connect(buildf, join, 0);
    w.connect(scan, prep, 0);
    w.connect(prep, join, 1);
    w.connect(join, sink, 0);
    (w, handle, sink, udf, buildf)
}

/// One scheduled run; returns (measured FRT s, end-to-end s, replans,
/// scales applied).
fn maestro_run(
    rows: usize,
    udf_cost_ns: u64,
    budget: usize,
) -> (f64, f64, usize, usize) {
    let (w, handle, sink, udf, buildf) = maestro_workflow(rows, udf_cost_ns);
    let mut cost = CostParams::new();
    cost.source_rows.insert(0, rows as f64);
    cost.tuple_cost.insert(udf, udf_cost_ns as f64 / 1_000.0);
    cost.selectivity.insert(buildf, 64.0 / rows as f64);
    let cfg = Config {
        max_workers: budget,
        ctrl_check_interval: 64,
        ..Config::default()
    };
    let sched = MaestroScheduler::new(cfg, cost);
    let t0 = Instant::now();
    let outcome = sched.run(w, &[sink]);
    let total = t0.elapsed().as_secs_f64();
    assert_eq!(
        handle.total(),
        rows as u64,
        "maestro bench dropped tuples (budget {budget})"
    );
    let applied = outcome
        .replans
        .iter()
        .flat_map(|r| r.decisions.iter())
        .filter(|d| d.applied)
        .count();
    (outcome.measured_frt, total, outcome.replans.len(), applied)
}

/// Static-schedule vs elastic-schedule FRT and end-to-end time on the
/// skewed multi-region workflow — recorded in BENCH_perf.json (the
/// acceptance row for elastic region scheduling is elastic FRT ≤
/// static FRT).
fn maestro_section(smoke: bool) -> MaestroBench {
    println!("--- maestro: static vs elastic region schedule (skewed multi-region workflow) ---");
    let rows = if smoke { 4_000 } else { 20_000 };
    let udf_cost_ns: u64 = if smoke { 15_000 } else { 25_000 };
    let budget = 8usize;
    let (static_frt, static_total, _, _) = maestro_run(rows, udf_cost_ns, 0);
    let (elastic_frt, elastic_total, replans, scales) =
        maestro_run(rows, udf_cost_ns, budget);
    println!(
        "  static : FRT {static_frt:.3}s | end-to-end {static_total:.3}s (authored counts)"
    );
    println!(
        "  elastic: FRT {elastic_frt:.3}s | end-to-end {elastic_total:.3}s \
         (budget {budget}, {replans} re-plans, {scales} scales applied)"
    );
    println!("  FRT speedup: {:.2}x\n", static_frt / elastic_frt);
    MaestroBench {
        rows,
        budget,
        static_frt_s: static_frt,
        static_total_s: static_total,
        elastic_frt_s: elastic_frt,
        elastic_total_s: elastic_total,
        replans,
        scales_applied: scales,
    }
}

struct FaultsBench {
    rows: usize,
    detection_ms_crash: f64,
    detection_ms_stall: f64,
    recovery_ms_checkpoint: f64,
    recovery_ms_scratch: f64,
    hb_off_tps: f64,
    hb_on_tps: f64,
}

/// One supervised run of the group-by pipeline with `plan` injected;
/// returns the end-to-end tuples/sec and the run's supervision stats.
fn faults_run(
    total: usize,
    plan: FaultPlan,
    checkpoint_interval_ms: u64,
    heartbeat_timeout_ms: u64,
) -> (f64, texera_amber::engine::ExecSummary) {
    let mut w = Workflow::new();
    let scan = w.add(OpSpec::source("scan", 2, move |idx, parts| {
        let rows: Vec<Tuple> = (0..total)
            .skip(idx)
            .step_by(parts)
            .map(|i| Tuple::new(vec![Value::Int(i as i64 % 64), Value::Int(i as i64 % 7)]))
            .collect();
        Box::new(VecSource::new(rows)) as Box<dyn TupleSource>
    }));
    let partial = w.add(OpSpec::unary("gb_partial", 2, PartitionScheme::RoundRobin, |_, _| {
        Box::new(GroupByPartial::new(0, 1, AggKind::Sum))
    }));
    let fin = w.add(
        OpSpec::unary("gb_final", 2, PartitionScheme::Hash { key: 0 }, |_, _| {
            Box::new(GroupByFinal::new(AggKind::Sum))
        })
        .with_blocking(vec![0]),
    );
    let handle = SinkHandle::new(0);
    let h = handle.clone();
    let sink = w.add(OpSpec::unary("sink", 1, PartitionScheme::RoundRobin, move |_, _| {
        Box::new(CollectSink::new(h.clone()))
    }));
    w.connect(scan, partial, 0);
    w.connect(partial, fin, 0);
    w.connect(fin, sink, 0);
    let cfg = Config {
        ft_log: true,
        heartbeat_timeout_ms,
        checkpoint_interval_ms,
        recovery_backoff_ms: 5,
        fault_plan: plan,
        ..Config::default()
    };
    let t0 = Instant::now();
    let summary = Execution::start(w, cfg).join();
    let secs = t0.elapsed().as_secs_f64().max(1e-9);
    (total as f64 / secs, summary)
}

/// Supervision cost numbers: failure-detection latency (crash vs
/// stall), recovery time with and without a retained automatic
/// checkpoint, and the steady-state overhead of the heartbeat sweep.
fn faults_section(smoke: bool) -> FaultsBench {
    println!("--- faults: detection latency, recovery time, heartbeat overhead ---");
    let rows = if smoke { 60_000 } else { 400_000 };
    let kill_at = (rows / 8) as u64;
    let one = |f: Fault| {
        let mut p = FaultPlan::default();
        p.push(f);
        p
    };
    // Crash: panic containment reports the failure immediately.
    let (_, crash_cp) = faults_run(
        rows,
        one(Fault::panic_at(WorkerId::new(1, 0), kill_at)),
        25,
        150,
    );
    // Same crash with automatic checkpoints off: scratch recovery.
    let (_, crash_scratch) = faults_run(
        rows,
        one(Fault::panic_at(WorkerId::new(1, 0), kill_at)),
        0,
        150,
    );
    // Stall: detection waits out the heartbeat timeout.
    let (_, stall) = faults_run(
        rows,
        one(Fault::stall_at(WorkerId::new(1, 0), kill_at, 400)),
        25,
        100,
    );
    // Steady state, no faults: heartbeat sweep off vs on.
    let (hb_off_tps, _) = faults_run(rows, FaultPlan::default(), 0, 0);
    let (hb_on_tps, _) = faults_run(rows, FaultPlan::default(), 0, 100);
    let out = FaultsBench {
        rows,
        detection_ms_crash: crash_cp.supervision.detection_ms_max,
        detection_ms_stall: stall.supervision.detection_ms_max,
        recovery_ms_checkpoint: crash_cp.supervision.recovery_ms_max,
        recovery_ms_scratch: crash_scratch.supervision.recovery_ms_max,
        hb_off_tps,
        hb_on_tps,
    };
    println!(
        "  detection: crash {:.2} ms | stall {:.2} ms (timeout 100 ms)",
        out.detection_ms_crash, out.detection_ms_stall
    );
    println!(
        "  recovery : checkpointed {:.1} ms | scratch {:.1} ms",
        out.recovery_ms_checkpoint, out.recovery_ms_scratch
    );
    println!(
        "  heartbeat: sweep off {:.0} t/s | sweep on {:.0} t/s ({:+.1}%)\n",
        out.hb_off_tps,
        out.hb_on_tps,
        (out.hb_on_tps / out.hb_off_tps - 1.0) * 100.0
    );
    out
}

/// One cell of the spill state-vs-budget sweep.
struct SpillRow {
    /// Resident state expressed as a multiple of the memory budget
    /// ("0.5x" = state fits in half the budget, no spilling).
    ratio: &'static str,
    budget_bytes: u64,
    tps: f64,
    bytes_spilled: u64,
    bytes_read_back: u64,
}

struct SpillBench {
    rows: usize,
    /// Budget high-water of the unbounded run — the resident state the
    /// sweep's budgets are derived from.
    resident_bytes: u64,
    unbounded_tps: f64,
    sweep: Vec<SpillRow>,
    /// Supervised crash mid-run under the tightest budget: recovery
    /// time from the latest automatic checkpoint, whose manifest
    /// replays the spilled partitions byte-exactly.
    recovery_ms: f64,
    recovery_bytes_spilled: u64,
}

/// One scan(2)→gb_partial(2)→gb_final(2)→sink run over `total` rows
/// with `keys` distinct groups (resident state scales with `keys`)
/// under `memory_budget_bytes` (0 = unbounded). `ft_log` turns on
/// supervision so an injected fault recovers instead of aborting.
fn spill_run(
    total: usize,
    keys: usize,
    memory_budget_bytes: u64,
    ft_log: bool,
    plan: FaultPlan,
    checkpoint_interval_ms: u64,
    heartbeat_timeout_ms: u64,
) -> (f64, texera_amber::engine::ExecSummary) {
    let mut w = Workflow::new();
    let scan = w.add(OpSpec::source("scan", 2, move |idx, parts| {
        let rows: Vec<Tuple> = (0..total)
            .skip(idx)
            .step_by(parts)
            .map(|i| Tuple::new(vec![Value::Int((i % keys) as i64), Value::Int(i as i64 % 7)]))
            .collect();
        Box::new(VecSource::new(rows)) as Box<dyn TupleSource>
    }));
    let partial = w.add(OpSpec::unary("gb_partial", 2, PartitionScheme::RoundRobin, |_, _| {
        Box::new(GroupByPartial::new(0, 1, AggKind::Sum))
    }));
    let fin = w.add(
        OpSpec::unary("gb_final", 2, PartitionScheme::Hash { key: 0 }, |_, _| {
            Box::new(GroupByFinal::new(AggKind::Sum))
        })
        .with_blocking(vec![0]),
    );
    let handle = SinkHandle::new(0);
    let h = handle.clone();
    let sink = w.add(OpSpec::unary("sink", 1, PartitionScheme::RoundRobin, move |_, _| {
        Box::new(CollectSink::new(h.clone()))
    }));
    w.connect(scan, partial, 0);
    w.connect(partial, fin, 0);
    w.connect(fin, sink, 0);
    let cfg = Config {
        memory_budget_bytes,
        ft_log,
        heartbeat_timeout_ms,
        checkpoint_interval_ms,
        recovery_backoff_ms: 5,
        fault_plan: plan,
        ..Config::default()
    };
    let t0 = Instant::now();
    let summary = Execution::start(w, cfg).join();
    let secs = t0.elapsed().as_secs_f64().max(1e-9);
    (total as f64 / secs, summary)
}

/// Out-of-core cost numbers: group-by throughput as resident state
/// grows past the memory budget (state at 0.5x / 2x / 8x of budget —
/// budgets derived from the unbounded run's measured high-water), and
/// recovery time from an automatic checkpoint whose manifest includes
/// spilled partitions.
fn spill_section(smoke: bool) -> SpillBench {
    println!("--- spill: throughput vs memory budget, recovery with spilled state ---");
    let rows = if smoke { 60_000 } else { 400_000 };
    let keys = rows / 4;
    // Unbounded run: measures resident state (budget high-water) and
    // the no-spill baseline throughput.
    let (unbounded_tps, base) = spill_run(rows, keys, 0, false, FaultPlan::default(), 0, 0);
    let resident = base.spill.budget_high_water.max(1);
    let mut sweep = Vec::new();
    for (ratio, budget) in [
        ("0.5x", resident * 2), // state is half the budget: stays resident
        ("2x", resident / 2),
        ("8x", resident / 8),
    ] {
        let budget = budget.max(1);
        let (tps, s) = spill_run(rows, keys, budget, false, FaultPlan::default(), 0, 0);
        println!(
            "  state {ratio:>4} of budget ({budget:>9} B): {tps:>9.0} t/s, \
             spilled {} B, read back {} B",
            s.spill.bytes_spilled, s.spill.bytes_read_back
        );
        sweep.push(SpillRow {
            ratio,
            budget_bytes: budget,
            tps,
            bytes_spilled: s.spill.bytes_spilled,
            bytes_read_back: s.spill.bytes_read_back,
        });
    }
    // Crash at rows/8 under the tightest budget with automatic
    // checkpoints on: recovery replays the checkpoint's spill-file
    // manifest on top of the in-memory snapshot.
    let mut plan = FaultPlan::default();
    plan.push(Fault::panic_at(WorkerId::new(1, 0), (rows / 8) as u64));
    let (_, rec) = spill_run(rows, keys, (resident / 8).max(1), true, plan, 25, 150);
    let out = SpillBench {
        rows,
        resident_bytes: resident,
        unbounded_tps,
        sweep,
        recovery_ms: rec.supervision.recovery_ms_max,
        recovery_bytes_spilled: rec.spill.bytes_spilled,
    };
    println!(
        "  unbounded: {:.0} t/s, resident state {} B",
        out.unbounded_tps, out.resident_bytes
    );
    println!(
        "  recovery (8x state, checkpoint 25 ms): {:.1} ms, {} B spilled\n",
        out.recovery_ms, out.recovery_bytes_spilled
    );
    out
}

/// One cell of the service concurrency sweep.
struct ServiceConcRow {
    concurrency: usize,
    mix: &'static str,
    p50_s: f64,
    p99_s: f64,
    agg_tuples_per_sec: f64,
}

struct ServiceBench {
    rows_per_job: usize,
    budget: usize,
    conc: Vec<ServiceConcRow>,
    /// Interactive job's measured first-response time (submit → first
    /// sink output, queue wait included) when it arrives mid-batch-scan
    /// under FIFO admission vs the priority/preemption policy.
    fifo_frt_s: f64,
    priority_frt_s: f64,
}

/// Multi-tenant serving layer: p50/p99 workflow latency and aggregate
/// throughput at increasing concurrency (uniform and heavy-tailed job
/// sizes) on one shared 12-worker budget, plus the FIFO-vs-priority
/// interactive first-response comparison the admission policy exists
/// for.
fn service_section(smoke: bool) -> ServiceBench {
    use texera_amber::service::{EngineService, ServiceConfig, Submission, TenantId, TenantQuota};

    println!("--- service: multi-tenant concurrency sweep ---");
    const BUDGET: usize = 12;
    let rows_per_job = if smoke { 5_000 } else { 20_000 };
    let levels: &[usize] = if smoke { &[1, 4, 16] } else { &[1, 16, 256] };

    // scan → gb_partial → gb_final → sink over `n` tuples.
    let flow = |n: usize| {
        let mut w = Workflow::new();
        let scan = w.add(OpSpec::source("scan", 2, move |idx, parts| {
            let rows: Vec<Tuple> = (0..n)
                .skip(idx)
                .step_by(parts)
                .map(|i| Tuple::new(vec![Value::Int(i as i64 % 53), Value::Int(i as i64)]))
                .collect();
            Box::new(VecSource::new(rows)) as Box<dyn TupleSource>
        }));
        let partial = w.add(OpSpec::unary("gb_partial", 2, PS::RoundRobin, |_, _| {
            Box::new(GroupByPartial::new(0, 1, AggKind::Sum))
        }));
        let fin = w.add(
            OpSpec::unary("gb_final", 2, PS::Hash { key: 0 }, |_, _| {
                Box::new(GroupByFinal::new(AggKind::Sum))
            })
            .with_blocking(vec![0]),
        );
        let handle = SinkHandle::new(0);
        let h2 = handle.clone();
        let sink = w.add(OpSpec::unary("sink", 1, PS::RoundRobin, move |_, _| {
            Box::new(CollectSink::new(h2.clone()))
        }));
        w.connect(scan, partial, 0);
        w.connect(partial, fin, 0);
        w.connect(fin, sink, 0);
        w
    };

    let mut conc = Vec::new();
    for &n_jobs in levels {
        for mix in ["uniform", "heavy_tailed"] {
            let cfg = ServiceConfig {
                engine: Config { max_workers: BUDGET, ..Config::default() },
                queue_cap: n_jobs.max(16),
                default_quota: TenantQuota {
                    max_queued: n_jobs.max(16),
                    ..TenantQuota::default()
                },
                ..ServiceConfig::default()
            };
            let svc = EngineService::start(cfg);
            let t0 = Instant::now();
            let mut ids = Vec::new();
            let mut total_rows = 0usize;
            for i in 0..n_jobs {
                // Heavy-tailed mix: every tenth job is 10× the size.
                let n = if mix == "heavy_tailed" && i % 10 == 9 {
                    rows_per_job * 10
                } else {
                    rows_per_job
                };
                total_rows += n;
                let id = svc
                    .submit(Submission::new(TenantId((i % 8) as u64), flow(n)))
                    .expect("admission");
                ids.push(id);
            }
            let mut lat = texera_amber::metrics::Summary::new();
            for id in ids {
                let r = svc.wait(id).expect("job finishes");
                assert!(r.error.is_none(), "{:?}", r.error);
                lat.record(r.total_s);
            }
            let wall = t0.elapsed().as_secs_f64();
            let row = ServiceConcRow {
                concurrency: n_jobs,
                mix,
                p50_s: lat.percentile(50.0),
                p99_s: lat.percentile(99.0),
                agg_tuples_per_sec: total_rows as f64 / wall,
            };
            println!(
                "conc {:>3} {:>12}: p50 {:.3}s p99 {:.3}s, {:.0} tuples/s aggregate",
                row.concurrency, row.mix, row.p50_s, row.p99_s, row.agg_tuples_per_sec
            );
            conc.push(row);
        }
    }

    // Interactive-under-batch: a long batch scan holds the budget; an
    // interactive job arrives mid-scan. FIFO admission makes it wait
    // the scan out; the priority policy preempts and serves it first.
    let frt_under = |fifo: bool| -> f64 {
        let cfg = ServiceConfig {
            engine: Config { max_workers: 4, ..Config::default() },
            fifo,
            ..ServiceConfig::default()
        };
        let svc = EngineService::start(cfg);
        let batch_rows = if smoke { 200_000 } else { 2_000_000 };
        let _batch = svc
            .submit(Submission::new(TenantId(0), flow(batch_rows)))
            .expect("admission");
        std::thread::sleep(Duration::from_millis(30));
        let inter = svc
            .submit(Submission::new(TenantId(1), flow(rows_per_job)).interactive())
            .expect("admission");
        let r = svc.wait(inter).expect("interactive finishes");
        assert!(r.error.is_none());
        assert!(r.workers_granted > 0);
        r.measured_frt.unwrap_or(r.total_s)
    };
    let fifo_frt_s = frt_under(true);
    let priority_frt_s = frt_under(false);
    println!(
        "interactive mid-batch frt: fifo {fifo_frt_s:.3}s vs priority {priority_frt_s:.3}s ({:.1}x)\n",
        fifo_frt_s / priority_frt_s
    );
    ServiceBench { rows_per_job, budget: BUDGET, conc, fifo_frt_s, priority_frt_s }
}

/// Write BENCH_perf.json (machine-readable perf trajectory) at the
/// repository root, so the bench trajectory accumulates across PRs.
/// The file's schema is documented in `docs/BENCH.md`.
#[allow(clippy::too_many_arguments)]
fn write_bench_json(
    rows: &[(usize, usize, f64)],
    baseline: f64,
    elastic: &ElasticBench,
    source_scale: &SourceScaleBench,
    migration: &[MigrationBench],
    shuffle: &[ShuffleRow],
    micro: &ScatterMicro,
    rvc: &RowVsColumnar,
    lanes: &LanesBench,
    maestro: &MaestroBench,
    faults: &FaultsBench,
    spill: &SpillBench,
    service: &ServiceBench,
) {
    let mut s = String::new();
    s.push_str("{\n  \"bench\": \"throughput_vs_batch_size\",\n");
    s.push_str("  \"pipeline\": \"scan->filter->sink (2 workers, 1M tuples)\",\n");
    s.push_str("  \"rows\": [\n");
    for (i, (batch, interval, tps)) in rows.iter().enumerate() {
        let speedup = if baseline > 0.0 { tps / baseline } else { 1.0 };
        s.push_str(&format!(
            "    {{\"batch_size\": {batch}, \"ctrl_check_interval\": {interval}, \
             \"tuples_per_sec\": {tps:.0}, \"speedup_vs_batch1\": {speedup:.2}}}{}\n",
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    s.push_str("  ],\n");
    s.push_str("  \"shuffle\": {\n");
    s.push_str(
        "    \"pipeline\": \"scan(2) --Hash(key)--> count-sink(4); skewed = 90% one hot key\",\n",
    );
    s.push_str("    \"rows\": [\n");
    for (i, r) in shuffle.iter().enumerate() {
        s.push_str(&format!(
            "      {{\"dist\": \"{}\", \"batch_size\": {}, \"tuples_per_sec\": {:.0}}}{}\n",
            r.dist,
            r.batch,
            r.tps,
            if i + 1 == shuffle.len() { "" } else { "," }
        ));
    }
    s.push_str("    ]\n  },\n");
    s.push_str("  \"scatter_micro\": {\n");
    s.push_str(
        "    \"setup\": \"1024-tuple batches, hash over 16 receivers; old = per-tuple route_with_base, new = hash_column + route_batch\",\n",
    );
    for (name, (old, new), comma) in [
        ("uniform", micro.uniform, ","),
        ("skewed", micro.skewed, ","),
    ] {
        s.push_str(&format!(
            "    \"{name}\": {{\"old_tuples_per_sec\": {old:.0}, \"new_tuples_per_sec\": {new:.0}, \"speedup\": {:.2}}}{comma}\n",
            new / old
        ));
    }
    let agg = (micro.uniform.1 / micro.uniform.0 + micro.skewed.1 / micro.skewed.0) / 2.0;
    s.push_str(&format!("    \"mean_speedup\": {agg:.2}\n  }},\n"));
    s.push_str("  \"row_vs_columnar\": {\n");
    s.push_str(
        "    \"setup\": \"Config::columnar off vs on, batch 1024; pipeline = scan->filter->sink (2 workers), shuffle = skewed scan(2) --Hash--> count-sink(4)\",\n",
    );
    s.push_str(&format!(
        "    \"pipeline\": {{\"row_tuples_per_sec\": {:.0}, \"columnar_tuples_per_sec\": {:.0}, \"speedup\": {:.2}}},\n",
        rvc.pipeline_row_tps,
        rvc.pipeline_col_tps,
        rvc.pipeline_col_tps / rvc.pipeline_row_tps
    ));
    s.push_str(&format!(
        "    \"shuffle\": {{\"row_tuples_per_sec\": {:.0}, \"columnar_tuples_per_sec\": {:.0}, \"speedup\": {:.2}}}\n  }},\n",
        rvc.shuffle_row_tps,
        rvc.shuffle_col_tps,
        rvc.shuffle_col_tps / rvc.shuffle_row_tps
    ));
    s.push_str("  \"lanes\": {\n");
    s.push_str(
        "    \"setup\": \"data ring of per-sender SPSC lanes, 1024-tuple batches, one consumer\",\n",
    );
    s.push_str(&format!(
        "    \"senders_1_tuples_per_sec\": {:.0}, \"senders_4_tuples_per_sec\": {:.0}, \"aggregate_speedup\": {:.2}\n  }},\n",
        lanes.senders_1_tps,
        lanes.senders_4_tps,
        lanes.senders_4_tps / lanes.senders_1_tps
    ));
    let es = if elastic.before_tps > 0.0 {
        elastic.after_tps / elastic.before_tps
    } else {
        0.0
    };
    s.push_str("  \"elastic_scaling\": {\n");
    s.push_str(
        "    \"pipeline\": \"scan->gb_partial(40us/tuple)->gb_final->sink, 90% hot key\",\n",
    );
    s.push_str(&format!(
        "    \"workers_before\": {}, \"workers_after\": {},\n",
        elastic.workers_before, elastic.workers_after
    ));
    s.push_str(&format!(
        "    \"tuples_per_sec_before\": {:.0}, \"tuples_per_sec_after\": {:.0},\n",
        elastic.before_tps, elastic.after_tps
    ));
    s.push_str(&format!(
        "    \"post_scale_speedup\": {es:.2}, \"fence_ms\": {:.1}\n  }},\n",
        elastic.fence_ms
    ));
    let ss = if source_scale.before_tps > 0.0 {
        source_scale.after_tps / source_scale.before_tps
    } else {
        0.0
    };
    s.push_str("  \"source_scale\": {\n");
    s.push_str(
        "    \"pipeline\": \"scan+parse(40us/tuple)->gb_final->sink, 90% hot key; the *scan* (source class) is scaled\",\n",
    );
    s.push_str(&format!(
        "    \"workers_before\": {}, \"workers_after\": {},\n",
        source_scale.workers_before, source_scale.workers_after
    ));
    s.push_str(&format!(
        "    \"tuples_per_sec_before\": {:.0}, \"tuples_per_sec_after\": {:.0},\n",
        source_scale.before_tps, source_scale.after_tps
    ));
    s.push_str(&format!(
        "    \"post_scale_speedup\": {ss:.2}, \"fence_ms\": {:.1}\n  }},\n",
        source_scale.fence_ms
    ));
    s.push_str("  \"migration\": {\n");
    s.push_str(
        "    \"pipeline\": \"scan+parse(40us/tuple)(2) -> filter(2) -> sink; one fresh run per delta kind; rates are the filter layer's\",\n",
    );
    s.push_str("    \"rows\": [\n");
    for (i, m) in migration.iter().enumerate() {
        s.push_str(&format!(
            "      {{\"kind\": \"{}\", \"applied\": {}, \"tuples_per_sec_before\": {:.0}, \
             \"tuples_per_sec_during\": {:.0}, \"tuples_per_sec_after\": {:.0}, \"fence_ms\": {:.1}}}{}\n",
            m.kind,
            m.applied,
            m.before_tps,
            m.during_tps,
            m.after_tps,
            m.fence_ms,
            if i + 1 == migration.len() { "" } else { "," }
        ));
    }
    s.push_str("    ]\n  },\n");
    s.push_str("  \"maestro\": {\n");
    s.push_str(
        "    \"pipeline\": \"scan->udf_build(25us/tuple)->buildf->join.build, scan->prep->join.probe (strict), join->sink; 90% hot key; probe path materialized\",\n",
    );
    s.push_str(&format!(
        "    \"rows\": {}, \"worker_budget\": {},\n",
        maestro.rows, maestro.budget
    ));
    s.push_str(&format!(
        "    \"static\": {{\"frt_s\": {:.4}, \"end_to_end_s\": {:.4}}},\n",
        maestro.static_frt_s, maestro.static_total_s
    ));
    s.push_str(&format!(
        "    \"elastic\": {{\"frt_s\": {:.4}, \"end_to_end_s\": {:.4}, \"replans\": {}, \"scales_applied\": {}}},\n",
        maestro.elastic_frt_s, maestro.elastic_total_s, maestro.replans, maestro.scales_applied
    ));
    s.push_str(&format!(
        "    \"frt_speedup\": {:.2}\n  }},\n",
        maestro.static_frt_s / maestro.elastic_frt_s
    ));
    s.push_str("  \"faults\": {\n");
    s.push_str(
        "    \"pipeline\": \"scan(2)->gb_partial(2)->gb_final(2)->sink; one panic or stall injected at rows/8\",\n",
    );
    s.push_str(&format!("    \"rows\": {},\n", faults.rows));
    s.push_str(&format!(
        "    \"detection_ms\": {{\"crash\": {:.2}, \"stall\": {:.2}}},\n",
        faults.detection_ms_crash, faults.detection_ms_stall
    ));
    s.push_str(&format!(
        "    \"recovery_ms\": {{\"with_checkpoint_25ms\": {:.1}, \"scratch\": {:.1}}},\n",
        faults.recovery_ms_checkpoint, faults.recovery_ms_scratch
    ));
    s.push_str(&format!(
        "    \"heartbeat\": {{\"sweep_off_tuples_per_sec\": {:.0}, \"sweep_100ms_tuples_per_sec\": {:.0}, \"overhead_pct\": {:.1}}}\n  }},\n",
        faults.hb_off_tps,
        faults.hb_on_tps,
        (1.0 - faults.hb_on_tps / faults.hb_off_tps) * 100.0
    ));
    s.push_str("  \"spill\": {\n");
    s.push_str(
        "    \"pipeline\": \"scan(2)->gb_partial(2)->gb_final(2)->sink, rows/4 distinct keys; budgets derived from the unbounded run's high-water\",\n",
    );
    s.push_str(&format!(
        "    \"rows\": {}, \"resident_state_bytes\": {}, \"unbounded_tuples_per_sec\": {:.0},\n",
        spill.rows, spill.resident_bytes, spill.unbounded_tps
    ));
    s.push_str("    \"state_vs_budget\": [\n");
    for (i, r) in spill.sweep.iter().enumerate() {
        s.push_str(&format!(
            "      {{\"state_over_budget\": \"{}\", \"budget_bytes\": {}, \"tuples_per_sec\": {:.0}, \
             \"bytes_spilled\": {}, \"bytes_read_back\": {}}}{}\n",
            r.ratio,
            r.budget_bytes,
            r.tps,
            r.bytes_spilled,
            r.bytes_read_back,
            if i + 1 == spill.sweep.len() { "" } else { "," }
        ));
    }
    s.push_str("    ],\n");
    s.push_str(&format!(
        "    \"recovery_with_spilled_state\": {{\"recovery_ms\": {:.1}, \"bytes_spilled\": {}}}\n  }},\n",
        spill.recovery_ms, spill.recovery_bytes_spilled
    ));
    s.push_str("  \"service\": {\n");
    s.push_str(
        "    \"pipeline\": \"scan(2)->gb_partial(2)->gb_final(2)->sink per job, shared EngineService; heavy_tailed = every 10th job 10x rows\",\n",
    );
    s.push_str(&format!(
        "    \"rows_per_job\": {}, \"worker_budget\": {},\n",
        service.rows_per_job, service.budget
    ));
    s.push_str("    \"concurrency\": [\n");
    for (i, r) in service.conc.iter().enumerate() {
        s.push_str(&format!(
            "      {{\"concurrency\": {}, \"mix\": \"{}\", \"workflow_latency_p50_s\": {:.4}, \
             \"workflow_latency_p99_s\": {:.4}, \"aggregate_tuples_per_sec\": {:.0}}}{}\n",
            r.concurrency,
            r.mix,
            r.p50_s,
            r.p99_s,
            r.agg_tuples_per_sec,
            if i + 1 == service.conc.len() { "" } else { "," }
        ));
    }
    s.push_str("    ],\n");
    s.push_str(&format!(
        "    \"interactive_mid_batch\": {{\"fifo_frt_s\": {:.4}, \"priority_frt_s\": {:.4}, \"frt_speedup\": {:.2}}}\n  }}\n",
        service.fifo_frt_s,
        service.priority_frt_s,
        service.fifo_frt_s / service.priority_frt_s
    ));
    s.push_str("}\n");
    // `cargo bench` runs with the crate dir as CWD; the trajectory
    // file lives at the repository root.
    let path = if std::path::Path::new("../ROADMAP.md").exists() {
        "../BENCH_perf.json"
    } else {
        "BENCH_perf.json"
    };
    match std::fs::write(path, &s) {
        Ok(()) => println!("(wrote {path})"),
        Err(e) => println!("(could not write {path}: {e})"),
    }
}

/// Partitioner routing nanoseconds per tuple.
fn routing_cost() {
    println!("--- partitioner routing cost ---");
    let t = Tuple::new(vec![Value::Int(123456)]);
    for (name, scheme) in [
        ("hash", PS::Hash { key: 0 }),
        ("round-robin", PS::RoundRobin),
        (
            "range",
            PS::Range {
                key: 0,
                bounds: (1..16).map(|i| Value::Int(i * 1000)).collect(),
            },
        ),
    ] {
        let mut p = Partitioner::new(scheme, 16, 0);
        let n = 3_000_000u64;
        let t0 = Instant::now();
        let mut acc = 0usize;
        for _ in 0..n {
            acc = acc.wrapping_add(p.route(&t));
        }
        let ns = t0.elapsed().as_nanos() as f64 / n as f64;
        println!("{name:>12}: {ns:>6.1} ns/tuple (acc {acc})");
    }
    println!();
}

/// Pause round-trip latency on an active pipeline.
fn pause_latency() {
    println!("--- pause/resume latency (active 8-worker pipeline) ---");
    let total = 4_000_000;
    let mut w = Workflow::new();
    let scan = w.add(OpSpec::source("scan", 2, move |idx, parts| {
        let rows: Vec<Tuple> = (0..total)
            .skip(idx)
            .step_by(parts)
            .map(|i| Tuple::new(vec![Value::Int(i as i64)]))
            .collect();
        Box::new(VecSource::new(rows)) as Box<dyn TupleSource>
    }));
    let filter = w.add(OpSpec::unary("filter", 8, PartitionScheme::RoundRobin, |_, _| {
        Box::new(Filter::new(0, Cmp::Ge, Value::Int(0)))
    }));
    let handle = SinkHandle::new(0);
    let h = handle.clone();
    let sink = w.add(OpSpec::unary("sink", 1, PartitionScheme::RoundRobin, move |_, _| {
        Box::new(CollectSink::new(h.clone()))
    }));
    w.connect(scan, filter, 0);
    w.connect(filter, sink, 0);
    let exec = Execution::start(w, Config::default());
    let mut s = texera_amber::metrics::Summary::new();
    for _ in 0..20 {
        std::thread::sleep(std::time::Duration::from_millis(5));
        s.record(exec.pause().as_secs_f64() * 1e6);
        exec.resume();
    }
    exec.join();
    println!(
        "p50 {:.0} µs | p99 {:.0} µs | max {:.0} µs\n",
        s.percentile(50.0),
        s.percentile(99.0),
        s.max()
    );
}

/// PJRT classifier throughput (L1/L2 artifact through the runtime).
fn pjrt_classifier_throughput() {
    println!("--- PJRT classifier throughput ---");
    if !texera_amber::runtime::pjrt::artifact_exists("artifacts", "classifier") {
        println!("skipped: run `make artifacts` first\n");
        return;
    }
    use texera_amber::operators::ml_infer::{BATCH, TOKENS};
    use texera_amber::runtime::{InferenceServer, Tensor};
    let server = InferenceServer::start("artifacts");
    let h = server.handle();
    let tokens = vec![7i32; BATCH * TOKENS];
    // Warm-up compiles the executable.
    h.run("classifier", vec![Tensor::I32(tokens.clone(), vec![BATCH as i64, TOKENS as i64])])
        .expect("inference");
    let n = 200;
    let t0 = Instant::now();
    for _ in 0..n {
        h.run("classifier", vec![Tensor::I32(tokens.clone(), vec![BATCH as i64, TOKENS as i64])])
            .expect("inference");
    }
    let per_batch = t0.elapsed().as_secs_f64() / n as f64;
    println!(
        "kernel (one-hot, TPU-shaped): {:.2} ms/batch → {:.0} tuples/s",
        per_batch * 1e3,
        BATCH as f64 / per_batch
    );
    // The CPU-tuned gather export (§Perf L2 iteration); identical math.
    if texera_amber::runtime::pjrt::artifact_exists("artifacts", "classifier_cpu") {
        h.run(
            "classifier_cpu",
            vec![Tensor::I32(tokens.clone(), vec![BATCH as i64, TOKENS as i64])],
        )
        .expect("inference");
        let t0 = Instant::now();
        for _ in 0..n {
            h.run(
                "classifier_cpu",
                vec![Tensor::I32(tokens.clone(), vec![BATCH as i64, TOKENS as i64])],
            )
            .expect("inference");
        }
        let pb = t0.elapsed().as_secs_f64() / n as f64;
        println!(
            "classifier_cpu (gather):      {:.2} ms/batch → {:.0} tuples/s ({:.1}x)",
            pb * 1e3,
            BATCH as f64 / pb,
            per_batch / pb
        );
    }
    println!();
}

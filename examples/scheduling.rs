//! Result-aware scheduling (Maestro, Ch. 4): a workflow whose region
//! graph is cyclic, the enumeration of materialization choices, their
//! estimated first-response times, and the scheduled execution of the
//! best one.
//!
//! ```text
//! cargo run --release --example scheduling
//! ```

use texera_amber::config::Config;
use texera_amber::engine::{OpSpec, PartitionScheme, Workflow};
use texera_amber::maestro::cost::CostParams;
use texera_amber::maestro::region_graph::region_graph;
use texera_amber::maestro::{enumerate_choices, first_response_time, MaestroScheduler};
use texera_amber::operators::basic::{Cmp, Filter};
use texera_amber::operators::{CollectSink, HashJoin, SinkHandle};
use texera_amber::tuple::{Tuple, Value};
use texera_amber::workloads::VecSource;

/// A self-join workflow (Fig. 4.1): one scan feeds both sides of a
/// strict hash join through different filters.
fn build(rows: usize) -> (Workflow, SinkHandle, usize) {
    let mut w = Workflow::new();
    let scan = w.add(OpSpec::source("scan", 2, move |idx, parts| {
        let rows: Vec<Tuple> = (0..rows)
            .filter(|i| i % parts == idx)
            .map(|i| Tuple::new(vec![Value::Int((i % 100) as i64), Value::Int(i as i64)]))
            .collect();
        Box::new(VecSource::new(rows))
    }));
    let probe_f = w.add(OpSpec::unary("filter_probe", 2, PartitionScheme::RoundRobin, |_, _| {
        Box::new(Filter::new(1, Cmp::Ge, Value::Int(0)))
    }));
    let build_f = w.add(OpSpec::unary("filter_build", 2, PartitionScheme::RoundRobin, |_, _| {
        Box::new(Filter::new(1, Cmp::Lt, Value::Int(100)))
    }));
    let join = w.add(OpSpec::binary(
        "join",
        2,
        [PartitionScheme::Hash { key: 0 }, PartitionScheme::Hash { key: 0 }],
        vec![0],
        |_, _| Box::new(HashJoin::new(0, 0).strict()),
    ));
    let handle = SinkHandle::new(0);
    let h = handle.clone();
    let sink = w.add(OpSpec::unary("sink", 1, PartitionScheme::RoundRobin, move |_, _| {
        Box::new(CollectSink::new(h.clone()))
    }));
    w.connect(scan, probe_f, 0);
    w.connect(scan, build_f, 0);
    w.connect(build_f, join, 0);
    w.connect(probe_f, join, 1);
    w.connect(join, sink, 0);
    (w, handle, sink)
}

fn main() {
    let rows = 50_000;
    let (w, handle, sink) = build(rows);

    // 1. The naive region graph is cyclic → no feasible schedule.
    let g = region_graph(&w);
    println!(
        "regions: {} | region graph acyclic: {}",
        g.regions.len(),
        g.is_acyclic()
    );

    // 2. Enumerate materialization choices and score them (§4.5).
    let mut cost = CostParams::new();
    cost.source_rows.insert(0, rows as f64);
    cost.selectivity.insert(2, 100.0 / rows as f64); // build filter tiny
    let choices = enumerate_choices(&w, 2);
    println!("\nmaterialization choices (edge sets) and estimated FRT:");
    for c in &choices {
        let (frt, bytes) = first_response_time(&w, c, &cost, &[sink]);
        let names: Vec<String> = c
            .iter()
            .map(|&ei| {
                let e = w.edges[ei];
                format!("{}→{}", w.ops[e.from].name, w.ops[e.to].name)
            })
            .collect();
        println!("  {names:?}: est FRT {frt:.0}, est bytes {bytes:.0}");
    }

    // 3. Schedule and run the best plan.
    let sched = MaestroScheduler::new(Config::default(), cost);
    let outcome = sched.run(w, &[sink]);
    println!(
        "\nchose {:?}; region order {:?}",
        outcome.choice, outcome.region_order
    );
    println!(
        "measured first-response {:.3}s, total {:.2?}, {} results, {} bytes materialized",
        outcome.measured_frt,
        outcome.summary.elapsed,
        handle.total(),
        outcome.mat_bytes.iter().sum::<u64>()
    );
}

//! Debug session (Amber, Ch. 2): pause a running workflow, investigate
//! worker state, modify an operator's logic at runtime, set local and
//! global conditional breakpoints — the paper's headline interactivity
//! features, driven programmatically.
//!
//! ```text
//! cargo run --release --example debug_session
//! ```

use std::sync::Arc;
use std::time::Duration;

use texera_amber::config::Config;
use texera_amber::engine::{Execution, OpSpec, PartitionScheme, Workflow};
use texera_amber::operators::{CountByKeySink, KeywordSearch, SinkHandle};
use texera_amber::tuple::Tuple;
use texera_amber::workloads::tweets::{self, TweetSource};
use texera_amber::workloads::TupleSource;

fn main() {
    let total = 2_000_000;
    let mut w = Workflow::new();
    let scan = w.add(OpSpec::source("tweet_scan", 2, move |idx, parts| {
        Box::new(TweetSource::new(total, parts, idx, 7)) as Box<dyn TupleSource>
    }));
    // The Ch. 1 "blunt" scenario: overly broad keyword.
    let keyword = w.add(OpSpec::unary(
        "keyword_search",
        3,
        PartitionScheme::RoundRobin,
        |_, _| Box::new(KeywordSearch::new(tweets::F_TEXT, &["blunt"])),
    ));
    let handle = SinkHandle::new(tweets::NUM_STATES);
    let h = handle.clone();
    let sink = w.add(OpSpec::unary("sink", 1, PartitionScheme::RoundRobin, move |_, _| {
        Box::new(CountByKeySink::new(h.clone(), tweets::F_LOCATION))
    }));
    w.connect(scan, keyword, 0);
    w.connect(keyword, sink, 0);

    let exec = Execution::start_scheduled(w, Config::default());

    // Conditional breakpoint BEFORE execution (§2.5): pause once the
    // keyword operator has produced 5,000 tuples.
    let bp = exec.set_count_breakpoint(keyword, 5_000);
    println!("registered global COUNT breakpoint #{bp} (5,000 tuples)");
    exec.start_sources(vec![scan]);

    let hit = exec.await_breakpoint();
    println!(
        "breakpoint #{} hit after {:.1?} — workflow paused",
        hit.id, hit.elapsed
    );

    // Investigate operator state while paused (§2.4.4).
    println!("\nworker stats at the breakpoint:");
    for (id, st) in exec.stats() {
        println!(
            "  {id}: processed={:>8} produced={:>7} queued={:>6}",
            st.processed, st.produced, st.queued
        );
    }

    // Modify the operator at runtime (§2.1): narrow the keywords so
    // Emily Blunt tweets stop matching.
    println!("\nnarrowing keywords: blunt → 'blunt talk'");
    exec.modify_operator(keyword, "keywords", "blunt talk");

    // Set a local breakpoint on suspicious tuples (§2.5.2): negative
    // follower counts would indicate parser bugs.
    exec.set_local_breakpoint(
        keyword,
        Some(Arc::new(|t: &Tuple| {
            t.get(tweets::F_FOLLOWERS).as_int().map(|f| f < 0).unwrap_or(false)
        })),
    );

    // Resume and measure pause latency once more mid-stream.
    exec.resume();
    std::thread::sleep(Duration::from_millis(50));
    let latency = exec.pause();
    println!("\nmid-run pause latency: {latency:.2?} (paper: sub-second)");
    exec.resume();

    let summary = exec.join();
    println!(
        "\ncompleted in {:.2?}; keyword operator produced {} tuples total",
        summary.elapsed,
        summary.produced(keyword)
    );
}

//! End-to-end driver: the full three-layer stack on a real small
//! workload, proving all layers compose.
//!
//! ```text
//! make artifacts && cargo run --release --example end_to_end
//! ```
//!
//! Workflow (the Ch. 4 climate-analysis shape with the Ch. 3 skewed
//! join and the Ch. 2 engine underneath):
//!
//! ```text
//! tweet scan ─ keyword("climate","fire","covid") ─ ML classify (PJRT)
//!      ─⋈ slang-by-location (build) ─ bar-chart sink
//! ```
//!
//! * **L1/L2**: the ML operator runs the AOT-compiled JAX/Pallas
//!   classifier through the PJRT runtime (`artifacts/classifier.hlo.txt`);
//!   Python never runs here.
//! * **L3 Reshape**: the join is location-skewed (California); Reshape
//!   detects and mitigates with SBR, keeping the observed CA:AZ ratio
//!   representative.
//! * **L3 Maestro**: the workflow is planned into regions and the build
//!   region is scheduled before the probe region.
//!
//! Reports: first-response time, end-to-end throughput, classifier
//! class histogram, join load-balance ratio, observed-vs-actual result
//! ratio — the paper's headline metrics.

use std::sync::Arc;
use std::time::Duration;

use texera_amber::config::Config;
use texera_amber::engine::{OpSpec, PartitionScheme, Workflow};
use texera_amber::maestro::cost::CostParams;
use texera_amber::maestro::MaestroScheduler;
use texera_amber::operators::ml_infer::MlInfer;
use texera_amber::operators::{CountByKeySink, HashJoin, KeywordSearch, SinkHandle};
use texera_amber::reshape::{Approach, ReshapePlugin};
use texera_amber::runtime::InferenceServer;
use texera_amber::tuple::{Tuple, Value};
use texera_amber::util::cli::Args;
use texera_amber::workloads::tweets::{self, TweetSource};
use texera_amber::workloads::{TupleSource, VecSource};

fn main() {
    let args = Args::from_env();
    let total: usize = args.get("tweets", 120_000);
    let join_workers: usize = args.get("workers", 8);
    if !texera_amber::runtime::pjrt::artifact_exists("artifacts", "classifier_cpu") {
        eprintln!("artifacts/classifier.hlo.txt missing — run `make artifacts` first");
        std::process::exit(1);
    }

    // L1/L2: bring up the PJRT inference server (compiles the HLO once).
    let server = InferenceServer::start("artifacts");
    let handle_for_ops = server.handle();

    // L3: the workflow.
    let mut w = Workflow::new();
    let slang: Arc<Vec<Tuple>> = Arc::new(tweets::slang_table());
    let s2 = slang.clone();
    let build_scan = w.add(OpSpec::source("slang_scan", 1, move |idx, parts| {
        let rows: Vec<Tuple> = s2
            .iter()
            .enumerate()
            .filter(|(i, _)| i % parts == idx)
            .map(|(_, t)| t.clone())
            .collect();
        Box::new(VecSource::new(rows)) as Box<dyn TupleSource>
    }));
    let tweet_scan = w.add(OpSpec::source("tweet_scan", 2, move |idx, parts| {
        Box::new(TweetSource::new(total, parts, idx, 2026)) as Box<dyn TupleSource>
    }));
    let keyword = w.add(OpSpec::unary(
        "keyword_search",
        2,
        PartitionScheme::RoundRobin,
        |_, _| Box::new(KeywordSearch::new(tweets::F_TEXT, &["climate", "fire", "covid"])),
    ));
    let classify = w.add(OpSpec::unary(
        "ml_classify",
        2,
        PartitionScheme::RoundRobin,
        move |_, _| {
            // classifier_cpu: same weights/math as `classifier`, exported
            // with gather instead of the TPU-shaped one-hot matmul —
            // 65x faster on the CPU PJRT backend (EXPERIMENTS.md §Perf).
            Box::new(MlInfer::new(tweets::F_TEXT, "classifier_cpu", handle_for_ops.clone()))
        },
    ));
    // The join models a moderately expensive per-tuple operation so it
    // can become the bottleneck on skewed keys (§3.3.1's assumption),
    // letting Reshape demonstrate mitigation.
    let join = w.add(OpSpec::binary(
        "join_slang",
        join_workers,
        [
            PartitionScheme::Hash { key: 0 },
            PartitionScheme::Hash { key: tweets::F_LOCATION },
        ],
        vec![0],
        |_, _| Box::new(HashJoin::new(0, tweets::F_LOCATION).with_probe_cost(20_000)),
    ));
    let results = SinkHandle::new(tweets::NUM_STATES);
    let class_hist = SinkHandle::new(texera_amber::operators::ml_infer::CLASSES);
    let r2 = results.clone();
    // Join output: slang(2) ++ classified tweet(7, class at index 6).
    let sink = w.add(OpSpec::unary("bar_chart", 1, PartitionScheme::RoundRobin, move |_, _| {
        Box::new(CountByKeySink::new(r2.clone(), 2 + tweets::F_LOCATION))
    }));
    let c2 = class_hist.clone();
    let class_sink = w.add(OpSpec::unary(
        "class_histogram",
        1,
        PartitionScheme::RoundRobin,
        move |_, _| Box::new(CountByKeySink::new(c2.clone(), 6)),
    ));
    w.connect(build_scan, join, 0);
    w.connect(tweet_scan, keyword, 0);
    w.connect(keyword, classify, 0);
    w.connect(classify, join, 1);
    w.connect(join, sink, 0);
    w.connect(classify, class_sink, 0);

    // Plan with Maestro; protect the join with Reshape.
    let cfg = Config { batch_size: 64, data_queue_cap: 16, ..Config::default() };
    let mut cost = CostParams::new();
    cost.source_rows.insert(build_scan, 50.0);
    cost.source_rows.insert(tweet_scan, total as f64);
    cost.tuple_cost.insert(classify, 20.0); // ML is the expensive op
    let sched = MaestroScheduler::new(cfg, cost);
    let (choice, est_frt) = sched.plan(&w, &[sink]);
    println!(
        "maestro plan: materialize {:?} (estimated FRT {est_frt:.0} cost units)",
        choice
    );

    let plugin = ReshapePlugin::new(join, Approach::SplitByRecords, true);
    let report = plugin.report();
    let t0 = std::time::Instant::now();
    let outcome = sched.run_pluggable(w, &[sink], &choice, est_frt, Some(Box::new(plugin)));
    let elapsed = t0.elapsed();

    // ---- headline metrics ----
    let summary = &outcome.summary;
    let matched = summary.produced(keyword);
    let classified = summary.produced(classify);
    println!("\n=== end-to-end run ===");
    println!("tweets scanned:            {total}");
    println!("keyword matches:           {matched}");
    println!("ML-classified (PJRT):      {classified}");
    println!("join results:              {}", results.total());
    println!("elapsed:                   {elapsed:.2?}");
    println!(
        "throughput:                {:.0} tweets/s end-to-end",
        total as f64 / elapsed.as_secs_f64()
    );
    println!("first response (sink):     {:.3}s", outcome.measured_frt);

    println!("\nclassifier class histogram:");
    for c in 0..texera_amber::operators::ml_infer::CLASSES {
        let n = class_hist.count_of(c);
        if n > 0 {
            println!("  class {c}: {n:>7}");
        }
    }

    // Reshape effect.
    let rep = report.lock().unwrap();
    println!("\nreshape: {} mitigation(s), {} phase-2 iterations", rep.mitigations.len(), rep.iterations);
    let ca_worker =
        (Value::Int(tweets::CA as i64).stable_hash() % join_workers as u64) as usize;
    if let Some((_, s, helpers)) = rep.mitigations.iter().find(|(_, s, _)| *s == ca_worker) {
        let get = |idx: usize| {
            summary
                .worker_stats
                .iter()
                .find(|(id, _)| id.op == join && id.idx == idx)
                .map(|(_, st)| st.processed as f64)
                .unwrap_or(0.0)
        };
        let (a, b) = (get(*s), get(helpers[0]));
        println!(
            "  CA worker {s} vs helper {}: processed {a:.0} / {b:.0} → load-balance ratio {:.2} (paper: ~0.92)",
            helpers[0],
            a.min(b) / a.max(b)
        );
    }
    let ratio = results.ratio(tweets::CA, tweets::AZ);
    println!(
        "  observed CA:AZ in results: {ratio:.2} (actual {}; unmitigated runs sit near 1.0 mid-run)",
        tweets::CA_AZ_RATIO
    );
    drop(rep);
    std::thread::sleep(Duration::from_millis(10));
}

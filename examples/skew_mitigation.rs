//! Skew mitigation (Reshape, Ch. 3): the W1 tweet⋈slang workflow with
//! a bottleneck join (as in §3.3.1), with and without Reshape.
//! Prints the observed CA:AZ result ratio over time (Fig. 3.16's
//! monitor) and the final load-balance between the California worker
//! and its helper (Fig. 3.20's metric).
//!
//! ```text
//! cargo run --release --example skew_mitigation [--tweets N] [--workers K]
//! ```

use std::time::Duration;

use texera_amber::config::Config;
use texera_amber::engine::Execution;
use texera_amber::flows::{tweet_join_costed, worker_of_key};
use texera_amber::reshape::{Approach, ReshapePlugin};
use texera_amber::util::cli::Args;
use texera_amber::workloads::tweets;

fn main() {
    let args = Args::from_env();
    let total: usize = args.get("tweets", 120_000);
    let workers: usize = args.get("workers", 8);
    // Make the join the bottleneck (~8µs per probe tuple).
    let probe_cost: u64 = args.get("cost-ns", 8_000);
    let cfg = Config {
        batch_size: 64,
        data_queue_cap: 16,
        ..Config::default()
    };
    let ca_worker = worker_of_key(tweets::CA as i64, workers);
    println!("W1: {total} tweets ⋈ slang on location, {workers} join workers, {probe_cost}ns/probe");
    println!("California is worker {ca_worker}'s key; actual CA:AZ = {}\n", tweets::CA_AZ_RATIO);

    for mitigate in [false, true] {
        let f = tweet_join_costed(total, workers, 0xC0FFEE, probe_cost);
        let label = if mitigate { "reshape " } else { "baseline" };
        let (exec, report) = if mitigate {
            let plugin = ReshapePlugin::new(f.focus, Approach::SplitByRecords, true);
            let rep = plugin.report();
            (
                Execution::start_with_plugin(f.workflow, cfg.clone(), Box::new(plugin)),
                Some(rep),
            )
        } else {
            (Execution::start(f.workflow, cfg.clone()), None)
        };
        // Sample the observed CA:AZ ratio during the run.
        print!("{label} | CA:AZ over time:");
        let mut samples = 0;
        while samples < 8 {
            std::thread::sleep(Duration::from_millis(150));
            let r = f.sink.ratio(tweets::CA, tweets::AZ);
            if r.is_finite() {
                print!(" {r:.2}");
                samples += 1;
            }
            if f.sink.total() as usize >= total {
                break;
            }
        }
        let summary = exec.join();
        let get = |idx: usize| {
            summary
                .worker_stats
                .iter()
                .find(|(id, _)| id.op == f.focus && id.idx == idx)
                .map(|(_, s)| s.processed as f64)
                .unwrap_or(0.0)
        };
        // Helper = the worker Reshape chose, or the least-loaded one.
        let helper = report
            .as_ref()
            .and_then(|r| {
                let rep = r.lock().unwrap();
                rep.mitigations
                    .iter()
                    .find(|(_, s, _)| *s == ca_worker)
                    .map(|(_, _, h)| h[0])
            })
            .unwrap_or_else(|| {
                (0..workers)
                    .filter(|&i| i != ca_worker)
                    .min_by(|&a, &b| get(a).partial_cmp(&get(b)).unwrap())
                    .unwrap()
            });
        let (a, b) = (get(ca_worker), get(helper));
        println!(
            "\n{label} | elapsed {:<8.2?} final CA:AZ {:.2}  CA-worker/helper load-balance {:.2}",
            summary.elapsed,
            f.sink.ratio(tweets::CA, tweets::AZ),
            a.min(b) / a.max(b)
        );
        if let Some(r) = report {
            let rep = r.lock().unwrap();
            println!(
                "{label} | mitigations: {:?}, phase-2 iterations: {}",
                rep.mitigations
                    .iter()
                    .map(|(t, s, h)| format!("t={t:.2}s w{s}→{h:?}"))
                    .collect::<Vec<_>>(),
                rep.iterations
            );
        }
        println!();
    }
}

//! Quickstart: build a small workflow, run it, read the results.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```
//!
//! Counts tweets per month from a synthetic 200k-tweet corpus:
//! scan → keyword filter → group-by(count) → sink.

use texera_amber::config::Config;
use texera_amber::engine::{Execution, OpSpec, PartitionScheme, Workflow};
use texera_amber::operators::{
    AggKind, CollectSink, GroupByFinal, GroupByPartial, KeywordSearch, SinkHandle,
};
use texera_amber::workloads::tweets::{self, TweetSource};
use texera_amber::workloads::TupleSource;

fn main() {
    let total = 200_000;

    // 1. Describe the workflow DAG.
    let mut w = Workflow::new();
    let scan = w.add(OpSpec::source("tweet_scan", 2, move |idx, parts| {
        Box::new(TweetSource::new(total, parts, idx, 42)) as Box<dyn TupleSource>
    }));
    let keyword = w.add(OpSpec::unary(
        "keyword_search",
        2,
        PartitionScheme::RoundRobin,
        |_, _| Box::new(KeywordSearch::new(tweets::F_TEXT, &["covid"])),
    ));
    let partial = w.add(OpSpec::unary(
        "count_partial",
        2,
        PartitionScheme::RoundRobin,
        |_, _| Box::new(GroupByPartial::new(tweets::F_MONTH, 0, AggKind::Count)),
    ));
    let fin = w.add(
        OpSpec::unary("count_final", 2, PartitionScheme::Hash { key: 0 }, |_, _| {
            Box::new(GroupByFinal::new(AggKind::Count))
        })
        .with_blocking(vec![0]),
    );
    let handle = SinkHandle::new(0);
    let h = handle.clone();
    let sink = w.add(OpSpec::unary("sink", 1, PartitionScheme::RoundRobin, move |_, _| {
        Box::new(CollectSink::new(h.clone()))
    }));
    w.connect(scan, keyword, 0);
    w.connect(keyword, partial, 0);
    w.connect(partial, fin, 0);
    w.connect(fin, sink, 0);

    // 2. Run it.
    let exec = Execution::start(w, Config::default());
    let summary = exec.join();

    // 3. Read the results.
    println!("tweets mentioning 'covid' per month:");
    let mut rows = handle.tuples();
    rows.sort_by_key(|t| t.get(0).as_int().unwrap());
    for row in rows {
        println!(
            "  month {:>2}: {:>6}",
            row.get(0).as_int().unwrap(),
            row.get(1).as_float().unwrap() as u64
        );
    }
    println!(
        "\n{total} tweets scanned in {:.2?} ({} matched the keyword)",
        summary.elapsed,
        summary.produced(keyword),
    );
}
